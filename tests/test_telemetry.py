"""Telemetry layer tests: registry semantics (labels, snapshots,
normalization, disabled-mode no-ops), the Session.stats/timings back-compat
views, thread-safety of the counter mirror under the BackgroundCompactor,
the retired-manifest GC-visibility gauges, and the planner's stall-imminent
signal."""
import gc
import re
import threading

import numpy as np

from repro.core import plan as P
from repro.core.frame import AFrame
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import Table
from repro.runtime import telemetry as tel

NO_COMPACT = lsm.CompactionPolicy(size_ratio=100.0, max_runs=64)


def _table(n=512):
    k = np.arange(n, dtype=np.int32)
    return Table({"k": k, "v": (k * 3).astype(np.int32)})


def _fed(sess, name="T", dv="t", n=512, runs=0, run_rows=64):
    sess.create_dataset(name, _table(n), dataverse=dv, primary="k")
    feed = Feed(sess, name, dv, flush_rows=10**9, policy=NO_COMPACT)
    for i in range(runs):
        lo = 10_000 + i * run_rows
        ks = np.arange(lo, lo + run_rows, dtype=np.int32)
        feed.push({"k": ks, "v": np.zeros(run_rows, np.int32)})
        feed.flush()
    return feed


# -- registry unit tests ------------------------------------------------------


def test_series_key_sorts_labels():
    assert tel.series_key("m", {}) == "m"
    assert tel.series_key("m", {"b": 2, "a": 1}) == "m{a=1,b=2}"


def test_counters_gauges_histograms_roundtrip():
    r = tel.MetricsRegistry()
    r.inc("c", kind="x")
    r.inc("c", 2, kind="x")
    r.set_gauge("g", 7.5)
    r.observe("h", 0.003)
    r.observe("h", 4.0)
    assert r.counter_value("c", kind="x") == 3
    assert r.counter_value("c", kind="missing") == 0
    assert r.gauge_value("g") == 7.5
    snap = r.snapshot()
    assert snap["counters"]["c{kind=x}"] == 3
    h = snap["histograms"]["h"]
    assert h["count"] == 2 and abs(h["sum"] - 4.003) < 1e-9
    assert h["min"] == 0.003 and h["max"] == 4.0
    assert sum(h["buckets"].values()) == 2
    # normalized form keeps the event count, zeroes every timing field
    hn = r.snapshot(normalize=True)["histograms"]["h"]
    assert hn == {"count": 2, "sum": 0.0, "min": 0.0, "max": 0.0}
    # snapshots are JSON-serializable as-is
    r.to_json()


def test_spans_nest_and_feed_histograms():
    r = tel.MetricsRegistry()
    with r.span("outer", q="1"):
        with r.span("inner"):
            pass
    spans = r.spans()
    assert [s["name"] for s in spans] == ["inner", "outer"]
    assert spans[0]["parent"] == "outer"
    assert spans[1]["parent"] is None
    assert spans[1]["labels"] == {"q": "1"}
    assert all(s["duration"] >= 0.0 for s in spans)
    assert r.snapshot()["histograms"]["outer_seconds{q=1}"]["count"] == 1
    # normalize zeroes span timings
    ns = r.snapshot(normalize=True)["spans"]
    assert all(s["start"] == 0.0 and s["duration"] == 0.0 for s in ns)


def test_disabled_mode_is_noop_for_spans_and_histograms():
    r = tel.MetricsRegistry(enabled=False)
    s = r.span("phase")
    assert s is tel.NOOP_SPAN  # shared singleton: no allocation per span
    with s:
        pass
    r.observe("h", 1.0)
    snap = r.snapshot()
    assert snap["histograms"] == {} and snap["spans"] == []
    # counters/gauges still record: they back the engine's stats surfaces
    r.inc("c")
    r.set_gauge("g", 1)
    assert r.counter_value("c") == 1 and r.gauge_value("g") == 1


def test_global_disable_keeps_session_stats_working():
    tel.set_enabled(False)
    try:
        sess = Session()
        sess.create_dataset("D", _table(), dataverse="off", primary="k")
        df = AFrame("off", "D", session=sess)
        assert len(df[(df["k"] >= 0) & (df["k"] <= 9)]) == 10
        assert sess.stats["compiles"] == 1 and sess.stats["optimizes"] == 1
        assert sess.point_lookup("off", "D", 5)["v"][0] == 15
        assert sess.stats["point_lookups"] == 1
        # no span landed while disabled
        assert not [s for s in tel.registry().spans("session.execute")
                    if s["labels"].get("sid") == sess.sid]
    finally:
        tel.set_enabled(True)


def test_registry_thread_safety():
    r = tel.MetricsRegistry()

    def work():
        for _ in range(2000):
            r.inc("t", worker="w")
            with r.span("s"):
                pass

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert r.counter_value("t", worker="w") == 16_000
    assert r.snapshot()["histograms"]["s_seconds"]["count"] == 16_000


# -- Session.stats / Session.timings as registry views ------------------------


def test_stats_view_seeded_and_counts_like_the_old_dict():
    sess = Session()
    # every key present and zero up front — including point_lookups, which
    # the old dict left unseeded (the .get() inconsistency)
    assert dict(sess.stats) == {"compiles": 0, "hits": 0, "optimizes": 0,
                                "plans": 0, "pruned_components": 0,
                                "point_lookups": 0}
    sess.create_dataset("S", _table(), dataverse="sv", primary="k")
    df = AFrame("sv", "S", session=sess)
    assert len(df[(df["k"] >= 3) & (df["k"] <= 30)]) == 28
    assert sess.stats["compiles"] == 1 and sess.stats["hits"] == 0
    assert len(df[(df["k"] >= 5) & (df["k"] <= 40)]) == 36
    assert sess.stats["hits"] == 1  # variant-level rebind
    assert sess.stats["compiles"] == 1
    # two sessions do not bleed into each other (the sid label)
    other = Session()
    assert other.stats["compiles"] == 0


def test_timings_view_tracks_last_timers():
    sess = Session()
    assert "last_execute" not in sess.timings
    sess.create_dataset("S", _table(), dataverse="tv", primary="k")
    assert sess.timings["last_create"] >= 0.0
    df = AFrame("tv", "S", session=sess)
    len(df[df["k"] >= 0])
    assert sess.timings["last_execute"] >= 0.0
    sess.point_lookup("tv", "S", 7)
    assert sess.timings["last_point_lookup"] >= 0.0
    assert set(sess.timings) == {"last_execute", "last_point_lookup",
                                 "last_create"}


def test_query_phase_spans_recorded():
    sess = Session()
    sess.create_dataset("S", _table(), dataverse="sp", primary="k")
    df = AFrame("sp", "S", session=sess)
    len(df[(df["k"] >= 0) & (df["k"] <= 9)])
    mine = [s for s in tel.registry().spans()
            if s["labels"].get("sid") == sess.sid]
    names = {s["name"] for s in mine}
    assert {"session.execute", "session.execute.run", "session.optimize",
            "session.plan", "session.prune", "session.compile"} <= names
    run = next(s for s in mine if s["name"] == "session.execute.run")
    assert run["parent"] == "session.execute"


def test_snapshot_determinism_across_sessions_normalized():
    """The same deterministic workload in two sessions yields identical
    normalized snapshots once the per-session sid label is masked."""

    def workload():
        sess = Session()
        sess.create_dataset("D", _table(), dataverse="det", primary="k")
        df = AFrame("det", "D", session=sess)
        len(df[(df["k"] >= 0) & (df["k"] <= 50)])
        len(df[(df["k"] >= 1) & (df["k"] <= 60)])
        sess.point_lookup("det", "D", 3)
        return sess.sid

    def capture(sid):
        tag = re.compile(r"(?<=[{,])sid=%s(?=[,}])" % re.escape(sid))
        snap = tel.snapshot(normalize=True, include_spans=False)
        out = {}
        for section in ("counters", "gauges", "histograms"):
            for k, v in snap[section].items():
                if tag.search(k):
                    out[tag.sub("sid=#", k)] = v
        return out

    a = capture(workload())
    b = capture(workload())
    assert a and a == b


# -- LSM / compactor mirrors --------------------------------------------------


def test_compactor_counters_mirror_stats_through_injected_fault():
    from repro.runtime.fault import FaultPlan

    before = {k: tel.counter_value(f"lsm.compactor.{k}_total")
              for k in ("faults", "retries", "compactions", "level_merges",
                        "conflicts", "giveups", "errors")}
    sess = Session()
    sess.create_dataset("F", _table(256), dataverse="bc", primary="k")
    sess.fault_plan = FaultPlan.once("mid-merge")
    with lsm.BackgroundCompactor(
            sess, policy=lsm.CompactionPolicy(size_ratio=0.0),
            backoff_s=0.001) as bc:
        feed = Feed(sess, "F", "bc", flush_rows=8,
                    policy=NO_COMPACT, compactor=bc)
        ks = np.arange(1000, 1008, dtype=np.int32)
        feed.push({"k": ks, "v": np.zeros(8, np.int32)})
        assert bc.wait_idle(30.0)
        assert bc.stats["faults"] >= 1 and bc.stats["retries"] >= 1
        # the registry mirror moved in lockstep with the stats dict
        for key, n0 in before.items():
            assert tel.counter_value(f"lsm.compactor.{key}_total") - n0 \
                == bc.stats[key], key


def test_flush_and_compaction_series():
    n0 = tel.counter_value("lsm.compaction.attempts_total", kind="full")
    sess = Session()
    feed = _fed(sess, name="L", dv="ls", runs=2)
    ds_label = "ls.L"
    assert tel.counter_value("ingest.flushes_total", dataset=ds_label) \
        == feed.stats["flushes"] == 2
    assert tel.counter_value("lsm.runs_built_total", dataset=ds_label) == 2
    assert tel.gauge_value("ingest.resident_runs", dataset=ds_label) == 2
    # the write-stall series exists (and is zero) without any stall
    assert tel.gauge_value("ingest.stall_seconds_total",
                           dataset=ds_label) == 0.0
    feed.compact()
    assert tel.counter_value("lsm.compaction.attempts_total",
                             kind="full") == n0 + 1
    assert tel.counter_value("lsm.compactions_total", kind="full") >= 1


def test_retired_manifest_gauges_lifecycle():
    """The PR 6 GC-visibility follow-up: device bytes reachable only through
    retired manifests are measured while a snapshot pins them, and drop to
    zero once the pin is released and the manifests are collected."""
    sess = Session()
    feed = _fed(sess, name="G", dv="gc", runs=2)
    snap = sess.catalog.snapshot()  # pins the pre-compaction manifest
    feed.compact()                  # retires it
    gs = sess.catalog.gc_stats()
    assert gs["manifests_retired"] >= 1
    assert gs["manifests_retired_pinned"] >= 1
    assert gs["retired_components"] >= 1
    assert gs["retired_component_bytes"] > 0
    assert tel.gauge_value("catalog.retired_component_bytes") \
        == gs["retired_component_bytes"]
    snap.release()
    del snap
    gc.collect()  # weak tracking: nothing retains the retired manifest now
    gs2 = sess.catalog.gc_stats()
    assert gs2["manifests_retired"] == 0
    assert gs2["retired_component_bytes"] == 0
    assert tel.gauge_value("catalog.retired_component_bytes") == 0


def test_retired_component_reclamation_lifecycle():
    """Active reclamation (the PR 9 satellite): a pinned snapshot holds the
    retired components' device buffers alive; the moment the last pin is
    released the catalog itself deletes them — no reliance on the Python GC
    — the retired-bytes gauge falls back to zero, the reclaimed counters
    advance, and the buffers really are device-deleted."""
    import jax

    c0 = tel.counter_value("catalog.reclaimed_components_total")
    b0 = tel.counter_value("catalog.reclaimed_bytes_total")
    sess = Session()
    feed = _fed(sess, name="R", dv="rc", runs=2)
    snap = sess.catalog.snapshot()  # pins the pre-compaction manifest
    pinned = list(snap.components("rc", "R"))
    feed.compact()  # retires the pinned manifest; its runs become garbage
    gs = sess.catalog.gc_stats()
    assert gs["retired_component_bytes"] > 0  # held ONLY by the pin
    # the pinned reader still sees live buffers
    for ds in pinned:
        for a in ds.table.columns.values():
            assert not (isinstance(a, jax.Array) and a.is_deleted())
    retired_runs = [ds for ds in pinned if "@run" in ds.name]
    assert retired_runs
    snap.release()  # last pin gone -> catalog reclaims eagerly, no gc.collect
    gs2 = sess.catalog.gc_stats()
    assert gs2["manifests_retired"] == 0
    assert gs2["retired_component_bytes"] == 0
    assert tel.gauge_value("catalog.retired_component_bytes") == 0
    assert tel.counter_value("catalog.reclaimed_components_total") > c0
    assert tel.counter_value("catalog.reclaimed_bytes_total") > b0
    for ds in retired_runs:  # buffers of compacted-away runs: device-deleted
        assert all(a.is_deleted() for a in ds.table.columns.values()
                   if isinstance(a, jax.Array))
    # the post-compaction base is untouched and queries still work
    df = AFrame("rc", "R", session=sess)
    assert len(df[df["v"] >= 0]) == 512 + 2 * 64


# -- planner stall-imminent signal -------------------------------------------


def test_stall_imminent_note_and_prune_report_gauge():
    from repro.core.physical_planner import (STALL_COMPONENT_CAP,
                                             STALL_WARN_FRAC)

    sess = Session(enable_index=False)
    _fed(sess, name="W", dv="st", runs=8)  # 9 components: pressure 0.75
    df = AFrame("st", "W", session=sess)
    plan = P.Agg(df[(df["v"] >= 0) & (df["v"] <= 10)]._plan,
                 [P.AggSpec("count", "count", None)])
    text = sess.explain(plan)
    assert "stall imminent" in text
    sess.execute(plan)
    rep = sess.last_prune_report
    assert rep["stall_imminent"]
    assert abs(rep["stall_pressure"] - 9 / STALL_COMPONENT_CAP) < 1e-9
    assert rep["stall_pressure"] >= STALL_WARN_FRAC
    assert tel.gauge_value("planner.stall_pressure") >= STALL_WARN_FRAC


def test_no_stall_note_below_warn_fraction():
    sess = Session()
    _fed(sess, name="C", dv="st2", runs=2)  # 3 components: pressure 0.25
    df = AFrame("st2", "C", session=sess)
    plan = P.Agg(df[(df["v"] >= 0) & (df["v"] <= 10)]._plan,
                 [P.AggSpec("count", "count", None)])
    text = sess.explain(plan)
    assert "stall imminent" not in text
    sess.execute(plan)
    assert not sess.last_prune_report["stall_imminent"]
    assert sess.last_prune_report["stall_pressure"] <= 0.5


# -- kernel launch counters ---------------------------------------------------


def test_kernel_launch_counters():
    sess = Session(mode="kernel", enable_index=False)
    sess.create_dataset("K", _table(8192), dataverse="kn", primary="k")
    df = AFrame("kn", "K", session=sess)
    before = sum(tel.registry().counters("kernel.launches_total").values())
    assert len(df[(df["k"] >= 0) & (df["k"] <= 100)]) == 101
    after = sum(tel.registry().counters("kernel.launches_total").values())
    assert after > before
    launches = tel.registry().counters("kernel.launches_total{")
    assert any("kernel=filter_count" in k for k in launches)
    grid = tel.registry().counters("kernel.grid_blocks_total")
    assert any("kernel=filter_count" in k for k in grid)
