"""Snapshot-isolated concurrent serving (the robustness tentpole): background
compaction off the ingest hot path, storage fault injection at every named
crash point, hard/soft state recovery, write-stall backpressure, and an
oracle-replay stress test across all three execution modes.

The oracle is a plain dict (key -> row) maintained by the test; every reader
observation must be bit-identical to it no matter where compaction, retries,
or injected crashes are in flight — compaction and recovery are invisible to
readers by construction."""
import threading
import time

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.frame import AFrame
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import Table
from repro.runtime import telemetry as tel
from repro.runtime.fault import STORAGE_FAULT_POINTS, FaultPlan, StorageFault

MODES = ["gspmd", "shard_map", "kernel"]

# never triggers on its own: tests drive compaction explicitly
DEFERRED = lsm.CompactionPolicy(size_ratio=100.0, max_runs=64)


def _session(mode, catalog=None):
    if mode == "shard_map":
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        return Session(mesh=mesh, mode="shard_map", catalog=catalog)
    return Session(mode=mode, catalog=catalog)


def _rows(keys, rng=None):
    """Schema: k (primary), v in [1, 100] (positive: a zero group-sum means
    an empty group), g in [0, 5)."""
    keys = np.asarray(keys, dtype=np.int32)
    if rng is None:
        vals = 1 + (keys.astype(np.int64) * 7 % 100).astype(np.int32)
    else:
        vals = rng.integers(1, 101, size=len(keys), dtype=np.int32)
    return {"k": keys, "v": vals, "g": (keys % 5).astype(np.int32)}


def _setup(mode, n=48, indexes=()):
    sess = _session(mode)
    rows = _rows(np.arange(n))
    sess.create_dataset("Live", Table(dict(rows)), dataverse="d",
                        primary="k", indexes=list(indexes))
    oracle = {int(k): (int(v), int(g))
              for k, v, g in zip(rows["k"], rows["v"], rows["g"])}
    return sess, oracle


def _expected(oracle):
    gsum = {}
    for v, g in oracle.values():
        gsum[g] = gsum.get(g, 0) + v
    return {"len": len(oracle),
            "sum": sum(v for v, _ in oracle.values()),
            "g2_count": sum(1 for _, g in oracle.values() if g == 2),
            "gsum": {g: s for g, s in gsum.items() if s != 0}}


def _observe(df):
    """One reader observation (each query pins its own snapshot)."""
    out = df.groupby("g").agg({"v": "sum"})
    gcol = np.asarray(out["g"]).tolist()
    vname = next(c for c in out if c != "g")
    vcol = np.asarray(out[vname]).tolist()
    return {"len": len(df),
            "sum": int(df["v"].sum()),
            "g2_count": len(df[df["g"] == 2]),
            "gsum": {int(g): int(s) for g, s in zip(gcol, vcol) if s != 0}}


# -- background compaction ---------------------------------------------------


def test_background_compactor_folds_runs_and_preserves_results():
    sess, oracle = _setup("gspmd")
    df = AFrame("d", "Live", session=sess)
    with lsm.BackgroundCompactor(
            sess, policy=lsm.LeveledCompactionPolicy(
                size_ratio=100.0, max_runs=64, level0_runs=2,
                level_ratio=2)) as bc:
        feed = Feed(sess, "Live", "d", flush_rows=8, policy=DEFERRED,
                    compactor=bc)
        for i in range(6):
            keys = np.arange(48 + 8 * i, 48 + 8 * (i + 1))
            rows = _rows(keys)
            feed.push(rows)
            for k, v, g in zip(rows["k"], rows["v"], rows["g"]):
                oracle[int(k)] = (int(v), int(g))
        assert bc.wait_idle(30.0)
        # leveled folding actually ran and reduced the component count
        assert bc.stats["level_merges"] >= 1
        assert len(sess.catalog.get("d", "Live").runs) < 6
    assert _observe(df) == _expected(oracle)


def test_no_reader_blocks_on_running_compaction(monkeypatch):
    """A reader landing MID-MERGE answers from its pinned snapshot in
    milliseconds while the worker spends >1s building the new base — the
    catalog lock is held for the O(datasets) swap only, never the build."""
    sess, oracle = _setup("gspmd", n=200)
    feed = Feed(sess, "Live", "d", flush_rows=20, policy=DEFERRED)
    for i in range(3):
        feed.push(_rows(np.arange(200 + 20 * i, 220 + 20 * i)))
    for k in range(200, 260):
        oracle[k] = (1 + k * 7 % 100, k % 5)
    reader = _session("gspmd", catalog=sess.catalog)
    df = AFrame("d", "Live", session=reader)
    assert _observe(df) == _expected(oracle)  # warm the reader's plan cache

    started = threading.Event()
    real = lsm._visible_columns

    def slow_visible(*a, **kw):
        started.set()
        time.sleep(0.35)  # 4 components -> the merge build takes >1.4s
        return real(*a, **kw)

    monkeypatch.setattr(lsm, "_visible_columns", slow_visible)
    with lsm.BackgroundCompactor(
            sess, policy=lsm.CompactionPolicy(size_ratio=0.0)) as bc:
        bc.notify("d", "Live")
        assert started.wait(10.0)
        t0 = time.perf_counter()
        got = _observe(df)
        dt = time.perf_counter() - t0
        assert got == _expected(oracle)
        assert dt < 0.3, f"reader blocked {dt:.2f}s on a running compaction"
        assert bc.wait_idle(30.0)
        assert bc.stats["compactions"] >= 1
    monkeypatch.setattr(lsm, "_visible_columns", real)
    assert len(sess.catalog.get("d", "Live").runs) == 0
    assert _observe(df) == _expected(oracle)


def test_write_stall_backpressures_writer_not_readers():
    """Past the hard run cap the WRITER blocks (bounded by the stall
    timeout); a concurrent reader still answers correctly."""
    sess, oracle = _setup("gspmd")
    # worker never folds anything -> the run count can only grow
    with lsm.BackgroundCompactor(sess, policy=DEFERRED) as bc:
        feed = Feed(sess, "Live", "d", flush_rows=8, policy=DEFERRED,
                    compactor=bc, stall_runs=2, stall_timeout_s=0.15)
        for i in range(3):
            rows = _rows(np.arange(48 + 8 * i, 56 + 8 * i))
            feed.push(rows)
            for k, v, g in zip(rows["k"], rows["v"], rows["g"]):
                oracle[int(k)] = (int(v), int(g))
        assert feed.stats["stalls"] >= 1
        assert feed.stats["stall_s"] > 0.0
        reader = _session("gspmd", catalog=sess.catalog)
        assert _observe(AFrame("d", "Live", session=reader)) == \
            _expected(oracle)


def test_proportional_stall_delay_curve():
    """The AsterixDB-style proportional delay: zero below the warning
    fraction of the cap, growing linearly with pressure, saturating at the
    configured maximum (the hard cap itself stays a blocking ceiling)."""
    from repro.core.physical_planner import STALL_WARN_FRAC
    from repro.engine.ingest import stall_delay

    assert stall_delay(0.0, 0.1) == 0.0
    assert stall_delay(STALL_WARN_FRAC - 0.01, 0.1) == 0.0  # under warn
    assert stall_delay(STALL_WARN_FRAC, 0.1) == 0.0         # curve starts
    mid = (STALL_WARN_FRAC + 1.0) / 2
    assert 0.0 < stall_delay(mid, 0.1) < 0.1
    assert stall_delay(1.0, 0.1) == pytest.approx(0.1)      # cap -> max
    assert stall_delay(5.0, 0.1) == pytest.approx(0.1)      # saturates
    assert stall_delay(1.0, 0.0) == 0.0                     # disabled
    # monotone non-decreasing across the whole pressure range
    samples = [stall_delay(p, 0.1) for p in np.linspace(0, 2, 41)]
    assert all(b >= a for a, b in zip(samples, samples[1:]))


def test_proportional_stall_slows_writer_before_hard_cap():
    """Approaching the cap, each flush sleeps a growing delay (soft stalls)
    instead of running full speed into the hard stall — and the delay is
    charged to the same stall accounting."""
    sess, oracle = _setup("gspmd")
    with lsm.BackgroundCompactor(sess, policy=DEFERRED) as bc:
        feed = Feed(sess, "Live", "d", flush_rows=8, policy=DEFERRED,
                    compactor=bc, stall_runs=8, stall_timeout_s=0.15,
                    stall_delay_s=0.02)
        for i in range(7):  # run count climbs 1..7: pressure crosses 0.75
            rows = _rows(np.arange(48 + 8 * i, 56 + 8 * i))
            feed.push(rows)
            for k, v, g in zip(rows["k"], rows["v"], rows["g"]):
                oracle[int(k)] = (int(v), int(g))
        assert feed.stats["stalls"] == 0          # never hit the ceiling
        assert feed.stats["soft_stalls"] >= 1     # but did slow down
        assert feed.stats["stall_s"] > 0.0
        reader = _session("gspmd", catalog=sess.catalog)
        assert _observe(AFrame("d", "Live", session=reader)) == \
            _expected(oracle)


def test_background_compactor_retries_through_injected_fault():
    """A mid-merge crash on the worker thread is absorbed by its bounded
    retry loop — the writer never sees it, and the fold still lands."""
    sess, oracle = _setup("gspmd")
    sess.fault_plan = FaultPlan.once("mid-merge")
    with lsm.BackgroundCompactor(
            sess, policy=lsm.CompactionPolicy(size_ratio=0.0),
            backoff_s=0.001) as bc:
        feed = Feed(sess, "Live", "d", flush_rows=8, policy=DEFERRED,
                    compactor=bc)
        rows = _rows(np.arange(48, 56))
        feed.push(rows)  # no StorageFault reaches the writer
        for k, v, g in zip(rows["k"], rows["v"], rows["g"]):
            oracle[int(k)] = (int(v), int(g))
        assert bc.wait_idle(30.0)
        assert bc.stats["faults"] >= 1 and bc.stats["retries"] >= 1
    assert len(sess.catalog.get("d", "Live").runs) == 0  # fold landed
    assert _observe(AFrame("d", "Live", session=sess)) == _expected(oracle)
    assert sess.fault_plan.fired == [("mid-merge", 0)]


def test_per_dataverse_compactor_isolation(monkeypatch):
    """The pending queue is sharded per dataverse: a stalled (long) merge in
    one dataverse must not delay another dataverse's compaction — each shard
    gets its own worker thread, created lazily at first notify."""
    sess, _ = _setup("gspmd")  # dataverse "d"
    rows = _rows(np.arange(48))
    sess.create_dataset("Other", Table(dict(rows)), dataverse="d2",
                        primary="k")

    release = threading.Event()
    entered = threading.Event()
    real = lsm._visible_columns

    def gated_visible(comp, *a, **kw):
        if comp.dataverse == "d":     # block ONLY dataverse d's merge
            entered.set()
            assert release.wait(30.0)
        return real(comp, *a, **kw)

    monkeypatch.setattr(lsm, "_visible_columns", gated_visible)
    with lsm.BackgroundCompactor(
            sess, policy=lsm.CompactionPolicy(size_ratio=0.0)) as bc:
        feed_d = Feed(sess, "Live", "d", flush_rows=8, policy=DEFERRED,
                      compactor=bc)
        feed_d.push(_rows(np.arange(48, 56)))
        assert entered.wait(10.0)     # d's worker is parked mid-merge
        assert tel.gauge_value("lsm.compactor.workers") == 1

        feed_d2 = Feed(sess, "Other", "d2", flush_rows=8, policy=DEFERRED,
                       compactor=bc)
        feed_d2.push(_rows(np.arange(48, 56)))
        # d2's shard compacts to quiescence while d is still blocked
        deadline = time.time() + 15.0
        while time.time() < deadline and \
                len(sess.catalog.get("d2", "Other").runs) > 0:
            time.sleep(0.02)
        assert len(sess.catalog.get("d2", "Other").runs) == 0, \
            "dataverse d2 compaction starved by d's stalled merge"
        assert tel.gauge_value("lsm.compactor.workers") == 2
        assert len(sess.catalog.get("d", "Live").runs) == 1  # still parked
        release.set()
        assert bc.wait_idle(30.0)
    assert len(sess.catalog.get("d", "Live").runs) == 0


# -- crash points on the synchronous path ------------------------------------


def _apply(oracle, rows=None, upserts=None, deletes=()):
    if rows is not None:
        for k, v, g in zip(rows["k"], rows["v"], rows["g"]):
            oracle[int(k)] = (int(v), int(g))
    if upserts is not None:
        for k, v, g in zip(upserts["k"], upserts["v"], upserts["g"]):
            oracle[int(k)] = (int(v), int(g))
    for k in deletes:
        oracle.pop(int(k), None)


@pytest.mark.parametrize("point", STORAGE_FAULT_POINTS)
def test_crash_at_every_point_keeps_readers_bit_identical(point):
    """The hard/soft split, end to end: a crash at ANY fault point leaves
    the manifest either fully old or fully new (never half), reader results
    bit-identical to the matching oracle state throughout, and recover() +
    the buffer-as-WAL discipline resumes ingestion exactly once."""
    sess, oracle = _setup("gspmd")
    # size_ratio=0 folds on every flush -> "mid-merge" is reachable inline
    feed = Feed(sess, "Live", "d", flush_rows=10**9,
                policy=lsm.CompactionPolicy(size_ratio=0.0))
    df = AFrame("d", "Live", session=sess)
    feed.push(_rows(np.arange(48, 56)))
    feed.flush()
    _apply(oracle, rows=_rows(np.arange(48, 56)))
    assert _observe(df) == _expected(oracle)

    # batch B mixes all three mutation kinds so annihilation bookkeeping,
    # anti arrays, and view deltas are all in play at the crash
    fresh = _rows(np.arange(56, 61))
    ups = {"k": np.arange(10, 16, dtype=np.int32),
           "v": np.full(6, 77, dtype=np.int32),
           "g": (np.arange(10, 16) % 5).astype(np.int32)}
    dels = np.array([3, 4, 50], dtype=np.int32)
    feed.push(fresh)
    feed.upsert(ups)
    feed.delete(dels)

    sess.fault_plan = FaultPlan.once(point)
    with pytest.raises(StorageFault):
        feed.flush()
    assert sess.fault_plan.fired == [(point, 0)]
    sess.fault_plan = None

    if point in ("flush", "pre-swap"):
        # nothing published: readers still see the pre-crash state ...
        assert _observe(df) == _expected(oracle)
        feed.flush()  # ... and the buffer is the WAL: replay applies once
        _apply(oracle, rows=fresh, upserts=ups, deletes=dels)
        assert _observe(df) == _expected(oracle)
    else:
        # the atomic swap committed the flush before the crash: readers see
        # the batch even though soft-state bookkeeping was cut short
        _apply(oracle, rows=fresh, upserts=ups, deletes=dels)
        assert _observe(df) == _expected(oracle)
        lsm.recover(sess, "d", "Live")
        assert _observe(df) == _expected(oracle)
        if point == "post-swap":
            feed.drop_buffer()  # committed: replaying would double-apply

    # the pipeline is healthy after recovery: mutate + flush again
    feed.push(_rows(np.arange(61, 66)))
    feed.delete(np.array([56], dtype=np.int32))
    feed.flush()
    _apply(oracle, rows=_rows(np.arange(61, 66)), deletes=[56])
    assert _observe(df) == _expected(oracle)
    assert len(df[df["k"] == 3]) == 0 and len(df[df["k"] == 10]) == 1


def test_recover_rebuilds_corrupted_soft_state_bit_identical():
    """Hard state (component tables + manifest) is sufficient: wipe every
    piece of soft state — index payloads, zone maps, host key copies, anti
    arrays, bookkeeping — and recover() rebuilds it all bit-identically."""
    sess, oracle = _setup("gspmd", indexes=["v"])
    feed = Feed(sess, "Live", "d", flush_rows=10**9, policy=DEFERRED)
    feed.push(_rows(np.arange(48, 60)))
    feed.upsert({"k": np.arange(5, 9, dtype=np.int32),
                 "v": np.full(4, 55, dtype=np.int32),
                 "g": (np.arange(5, 9) % 5).astype(np.int32)})
    feed.delete(np.array([20, 21], dtype=np.int32))
    feed.flush()
    _apply(oracle, rows=_rows(np.arange(48, 60)),
           upserts={"k": np.arange(5, 9), "v": np.full(4, 55),
                    "g": np.arange(5, 9) % 5},
           deletes=[20, 21])
    df = AFrame("d", "Live", session=sess)

    def suite():
        obs = _observe(df)
        obs["v_range"] = len(df[(df["v"] >= 10) & (df["v"] <= 60)])
        obs["probe"] = (len(df[df["k"] == 20]), len(df[df["k"] == 5]))
        return obs

    before = suite()
    comps = sess.catalog.components("d", "Live")
    assert any(c.anti_keys_arr is not None for c in comps)
    for comp in comps:
        comp.live_rows = 0
        comp.annihilated_rows = 10 ** 6
        comp.annihilated_keys = set()
        comp.host_keys = None
        comp.block_zones = None
        if comp.anti_keys_arr is not None:
            comp.anti_keys_arr = comp.anti_keys_arr[:0]
        for info in comp.indexes.values():
            if info.kind == "secondary":
                info.sorted_keys = None
                info.row_ids = None
                info.zone_min = None
                info.zone_max = None
    lsm.recover(sess, "d", "Live")
    assert suite() == before
    for comp in comps:
        assert comp.host_keys is not None
        for info in comp.indexes.values():
            if info.kind == "secondary":
                assert info.sorted_keys is not None
    assert any(len(np.asarray(c.anti_keys_arr)) for c in comps
               if c.anti_keys_arr is not None)


# -- oracle-replay stress: concurrent compactor, faults, all three modes -----


def _stress(mode, seed, n_ops=9, fault=None, fault_at=0):
    """Drive a random op sequence against a writer with a REAL background
    compactor racing (leveled, fanin 2 — folds constantly), a shared-catalog
    reader observing after every flush, and optionally one injected crash.
    Every observation must equal the dict oracle exactly: compaction is
    result-preserving, so the race never shows."""
    rng = np.random.default_rng(seed)
    sess, oracle = _setup(mode)
    shadow = dict(oracle)  # oracle ∪ buffered-but-unflushed ops
    reader = _session(mode, catalog=sess.catalog)
    df = AFrame("d", "Live", session=reader)
    next_k = 48
    flush_i = 0
    with lsm.BackgroundCompactor(
            sess, policy=lsm.LeveledCompactionPolicy(
                size_ratio=6.0, max_runs=64, level0_runs=2, level_ratio=2),
            backoff_s=0.001) as bc:
        feed = Feed(sess, "Live", "d", flush_rows=10**9, policy=DEFERRED,
                    compactor=bc)
        ops = rng.choice(["push", "upsert", "delete", "flush"], size=n_ops,
                         p=[0.35, 0.2, 0.15, 0.3])
        for op in list(ops) + ["flush"]:
            if op == "push":
                n = int(rng.integers(1, 10))
                rows = _rows(np.arange(next_k, next_k + n), rng)
                next_k += n
                feed.push(rows)
                _apply(shadow, rows=rows)
            elif op == "upsert":
                keys = sorted(shadow)
                if not keys:
                    continue
                pick = rng.choice(keys, size=min(6, len(keys)), replace=False)
                ups = _rows(np.sort(pick), rng)
                feed.upsert(ups)
                _apply(shadow, upserts=ups)
            elif op == "delete":
                keys = sorted(shadow)
                if not keys:
                    continue
                pick = np.sort(rng.choice(keys, size=min(4, len(keys)),
                                          replace=False)).astype(np.int32)
                feed.delete(pick)
                _apply(shadow, deletes=pick)
            else:
                if fault is not None and flush_i == fault_at:
                    sess.fault_plan = FaultPlan(schedule={fault: (0,)})
                try:
                    feed.flush()
                except StorageFault:
                    # the crash hit the WRITER path (worker-side crashes are
                    # absorbed by its retry loop and never surface here)
                    pt = sess.fault_plan.fired[-1][0]
                    sess.fault_plan = None
                    if pt == "post-swap":
                        # committed: repair soft state, don't replay the WAL
                        lsm.recover(sess, "d", "Live")
                        feed.drop_buffer()
                    else:
                        feed.flush()  # nothing landed: replay the buffer
                sess.fault_plan = None
                flush_i += 1
                oracle = dict(shadow)  # every flush path applies exactly once
                assert _observe(df) == _expected(oracle), \
                    f"[{mode} seed={seed}] reader diverged after flush {flush_i}"
        assert bc.wait_idle(30.0)
        # quiescent end state: a FRESH reader session agrees too
        final = _expected(dict(shadow))
        assert _observe(df) == final
        df2 = AFrame("d", "Live",
                     session=_session(mode, catalog=sess.catalog))
        assert _observe(df2) == final


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("seed", [0, 1])
def test_stress_concurrent_ops_match_oracle(mode, seed):
    _stress(mode, seed)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("fault", STORAGE_FAULT_POINTS)
def test_stress_with_injected_crash_matches_oracle(mode, fault):
    _stress(mode, seed=2, fault=fault, fault_at=1)


def test_stress_hypothesis_random_schedules():
    """Property form of the stress driver (optional dependency, like the
    other hypothesis suites): random seeds, op counts, and crash points."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10**6), n_ops=st.integers(4, 12),
           fault=st.sampled_from((None,) + STORAGE_FAULT_POINTS),
           fault_at=st.integers(0, 2))
    def run(seed, n_ops, fault, fault_at):
        _stress("gspmd", seed, n_ops=n_ops, fault=fault, fault_at=fault_at)

    run()
