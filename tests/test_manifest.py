"""Manifest / snapshot-isolation unit coverage (core/catalog.py): atomic
publish-then-retire swaps, LSN monotonicity, pinned snapshots, stable
component addressing across compaction, Catalog.get error paths, and the
open_widen dtype contract."""
import numpy as np
import pytest

from repro.core.catalog import Catalog, Manifest, Snapshot, open_widen
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import Table


def _fresh(n=50, name="Live", primary="k", policy=None, flush_rows=10):
    sess = Session()
    sess.create_dataset(
        name, Table({"k": np.arange(n, dtype=np.int32),
                     "v": (np.arange(n, dtype=np.int32) * 3) % 17}),
        dataverse="d", primary=primary)
    feed = Feed(sess, name, "d", flush_rows=flush_rows,
                policy=policy or lsm.CompactionPolicy(size_ratio=100.0,
                                                      max_runs=64))
    return sess, feed


def _push(feed, lo, n=10):
    feed.push({"k": np.arange(lo, lo + n, dtype=np.int32),
               "v": (np.arange(lo, lo + n, dtype=np.int32) * 3) % 17})


# -- manifest lifecycle ------------------------------------------------------


def test_flush_publishes_new_manifest_and_retires_old():
    sess, feed = _fresh()
    ds = sess.catalog.get("d", "Live")
    m0 = ds.manifest
    assert isinstance(m0, Manifest) and m0.runs == () and not m0.retired
    _push(feed, 50)
    m1 = sess.catalog.get("d", "Live").manifest
    assert m1 is not m0 and m1.lsn > m0.lsn
    assert m0.retired and not m1.retired
    assert [r.name for r in m1.runs] == ["Live@run0"]
    # the retired manifest still describes exactly the old component set
    assert m0.components == (ds,)


def test_lsn_strictly_monotone_across_publishes():
    sess, feed = _fresh()
    seen = [sess.catalog.get("d", "Live").manifest.lsn]
    for i in range(3):
        _push(feed, 50 + 10 * i)
        seen.append(sess.catalog.get("d", "Live").manifest.lsn)
    feed.compact()
    seen.append(sess.catalog.get("d", "Live").manifest.lsn)
    assert seen == sorted(seen) and len(set(seen)) == len(seen)


def test_snapshot_pins_old_manifest_across_flush_and_compaction():
    sess, feed = _fresh()
    _push(feed, 50)
    snap = sess.catalog.snapshot()
    pinned = snap.manifest("d", "Live")
    assert pinned.pins == 1
    before = [c.name for c in snap.components("d", "Live")]
    _push(feed, 60)
    feed.compact()
    # the live catalog moved on ...
    assert [c.name for c in sess.catalog.components("d", "Live")] == ["Live"]
    # ... but the pinned snapshot still reads the exact old component set
    assert [c.name for c in snap.components("d", "Live")] == before
    assert snap.get("d", "Live@run0") is pinned.runs[0]
    assert pinned.retired
    snap.release()
    assert pinned.pins == 0
    snap.release()  # idempotent
    assert pinned.pins == 0


def test_snapshot_does_not_see_later_datasets():
    sess, _ = _fresh()
    with sess.catalog.snapshot() as snap:
        sess.create_dataset("Late", Table({"k": np.arange(5)}), dataverse="d")
        with pytest.raises(KeyError):
            snap.get("d", "Late")
    assert sess.catalog.get("d", "Late") is not None


def test_dataset_runs_property_is_a_read_only_view():
    sess, feed = _fresh()
    _push(feed, 50)
    ds = sess.catalog.get("d", "Live")
    runs = ds.runs
    runs.append("garbage")  # mutating the copy changes nothing
    assert [r.name for r in ds.runs] == ["Live@run0"]


# -- stable component addressing ---------------------------------------------


def test_get_component_address_error_paths():
    sess, feed = _fresh()
    _push(feed, 50)
    cat = sess.catalog
    assert cat.get("d", "Live@run0").uid == 0
    with pytest.raises(KeyError):  # out-of-range uid
        cat.get("d", "Live@run99")
    with pytest.raises(KeyError):  # malformed suffix: no uid
        cat.get("d", "Live@run")
    with pytest.raises(KeyError):  # malformed suffix: non-numeric uid
        cat.get("d", "Live@runx")
    with pytest.raises(KeyError):  # malformed suffix: not a run address
        cat.get("d", "Live@foo")
    with pytest.raises(KeyError):  # unknown dataset
        cat.get("d", "Nope@run0")
    with pytest.raises(KeyError):  # unknown dataverse
        cat.get("nope", "Live@run0")
    # the same contract through a snapshot
    with cat.snapshot() as snap:
        with pytest.raises(KeyError):
            snap.get("d", "Live@run99")
        with pytest.raises(KeyError):
            snap.get("d", "Nope@run0")


def test_stable_address_survives_level_merge_between_creation_and_resolution():
    """A leveled merge folds runs 0..2 into a fresh run while run 3's
    address — taken BEFORE the merge — keeps resolving to the same object;
    the merged-away addresses go stale (KeyError), never alias."""
    sess, feed = _fresh(flush_rows=10)
    for i in range(4):
        _push(feed, 50 + 10 * i)
    cat = sess.catalog
    survivor = cat.get("d", "Live@run3")
    merged_away = [cat.get("d", f"Live@run{i}") for i in range(3)]
    lsm.merge_runs(sess, cat.get("d", "Live"), 0, 3, level=1)
    # the survivor keeps its stable address AND identity
    assert cat.get("d", "Live@run3") is survivor
    # the merged run took a fresh uid — it never shadows a retired address
    names = [r.name for r in cat.get("d", "Live").runs]
    assert names == ["Live@run4", "Live@run3"]
    assert cat.get("d", "Live@run4").uid == 4
    for i in range(3):
        with pytest.raises(KeyError):
            cat.get("d", f"Live@run{i}")
    assert all(m.name == f"Live@run{i}" for i, m in enumerate(merged_away))


def test_full_compaction_never_recycles_uids():
    sess, feed = _fresh()
    _push(feed, 50)
    _push(feed, 60)
    feed.compact()
    _push(feed, 70)
    # uids 0 and 1 were consumed pre-compaction; the next flush takes 2
    assert [r.name for r in sess.catalog.get("d", "Live").runs] == ["Live@run2"]
    with pytest.raises(KeyError):
        sess.catalog.get("d", "Live@run0")


# -- shared-catalog reader sessions ------------------------------------------


def test_reader_session_shares_catalog_and_sees_writes():
    from repro.core.frame import AFrame

    sess, feed = _fresh()
    reader = Session(catalog=sess.catalog)
    df = AFrame("d", "Live", session=reader)
    assert len(df) == 50
    _push(feed, 50)
    assert len(df) == 60
    feed.compact()
    assert len(df) == 60


# -- open_widen dtype contract (regression: docs said float64) ---------------


def test_open_widen_casts_integers_to_float32():
    t = Table({"k": np.arange(8, dtype=np.int64),
               "f": np.ones(8, dtype=np.float64),
               "s": np.zeros((8, 16), dtype=np.uint8)})
    w = open_widen(t)
    assert w.columns["k"].dtype == np.float32  # the TPU-native lane dtype
    assert w.meta["k"].dtype == np.dtype(np.float32)
    assert w.columns["f"].dtype == t.columns["f"].dtype  # floats untouched
    assert w.columns["s"].dtype == np.uint8  # strings untouched
    np.testing.assert_array_equal(np.asarray(w.columns["k"]),
                                  np.arange(8, dtype=np.float32))


# -- FaultTolerantLoop config default (regression: shared instance) ----------


def test_fault_tolerant_loop_config_not_shared():
    from repro.runtime.fault import FaultTolerantLoop

    class _NullCkpt:
        def save(self, *a, **k):
            pass

    a = FaultTolerantLoop(lambda *a: None, _NullCkpt())
    b = FaultTolerantLoop(lambda *a: None, _NullCkpt())
    assert a.cfg is not b.cfg
    a.cfg.ckpt_every = 999
    assert b.cfg.ckpt_every != 999
