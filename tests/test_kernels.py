"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret=True),
plus engine-level checks that both execution modes sit on the same kernel
semantics (mode="kernel" lowers onto these kernels; mode="gspmd" onto the
generic jnp operators — results must agree with the numpy oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.filter_count import filter_count
from repro.kernels.flash_attention import flash_mha_fwd
from repro.kernels.merge_join import merge_join_count
from repro.kernels.segment_agg import segment_agg
from repro.kernels.topk_mask import topk_merge

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n,k,block", [(1000, 1, 256), (5000, 3, 512),
                                       (8192, 2, 4096), (300, 4, 128)])
def test_filter_count_sweep(n, k, block):
    cols = jnp.asarray(RNG.integers(0, 50, (k, n)), jnp.int32)
    bounds = jnp.asarray(np.sort(RNG.integers(0, 50, (k, 2)), axis=1), jnp.int32)
    nv = int(n * 0.9)
    got = filter_count(cols, bounds, nv, block=block)
    want = ref.filter_count(cols, bounds, nv)
    assert int(got) == int(want)


@pytest.mark.parametrize("n,c,g,block", [(1000, 1, 7, 256), (4096, 4, 20, 1024),
                                         (513, 3, 100, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_agg_sweep(n, c, g, block, dtype):
    vals = jnp.asarray(RNG.normal(size=(n, c)), dtype)
    gids = jnp.asarray(RNG.integers(0, g, n), jnp.int32)
    nv = n - 5
    got = segment_agg(vals.astype(jnp.float32), gids, g, nv, block=block)
    want = ref.segment_agg(vals.astype(jnp.float32), gids, g, nv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("nl,nr,dom,block", [(500, 700, 50, 128),
                                             (2048, 2048, 5000, 512),
                                             (100, 4000, 10, 256)])
def test_merge_join_sweep(nl, nr, dom, block):
    l = np.sort(RNG.integers(0, dom, nl)).astype(np.int32)
    r = np.sort(RNG.integers(0, dom, nr)).astype(np.int32)
    got = merge_join_count(jnp.asarray(l), jnp.asarray(r), nl - 3, nr - 7, block=block)
    want = ref.merge_join_count(jnp.asarray(l), jnp.asarray(r), nl - 3, nr - 7)
    assert int(got) == int(want)


@pytest.mark.parametrize("n,k,block", [(2048, 5, 512), (4096, 1, 1024),
                                       (1000, 8, 256)])
def test_topk_sweep(n, k, block):
    sc = jnp.asarray(RNG.normal(size=n), jnp.float32)
    mask = jnp.asarray(RNG.random(n) > 0.2)
    nv = n - 11
    v, i = topk_merge(sc, mask, nv, k, block=block)
    smask = np.where(np.asarray(mask) & (np.arange(n) < nv), np.asarray(sc), -np.inf)
    want = np.sort(smask)[::-1][:k]
    np.testing.assert_allclose(np.asarray(v), want, rtol=1e-6)
    # indices point at the right values
    np.testing.assert_allclose(smask[np.asarray(i)], want, rtol=1e-6)


@pytest.mark.parametrize("B,H,KV,S,D,bq,bk", [
    (1, 2, 2, 128, 16, 32, 32),    # MHA
    (2, 4, 2, 256, 32, 64, 128),   # GQA, uneven blocks
    (1, 8, 1, 64, 64, 64, 16),     # MQA, single q block
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_fwd_sweep(B, H, KV, S, D, bq, bk, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, S, D)), dtype) * 0.3
    k = jnp.asarray(RNG.normal(size=(B, KV, S, D)), dtype) * 0.3
    v = jnp.asarray(RNG.normal(size=(B, KV, S, D)), dtype) * 0.3
    out, lse = flash_mha_fwd(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.mha(q, k, v, causal=causal)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_xla_twin_matches_pallas():
    q = jnp.asarray(RNG.normal(size=(2, 4, 128, 32)), jnp.float32) * 0.4
    k = jnp.asarray(RNG.normal(size=(2, 2, 128, 32)), jnp.float32) * 0.4
    v = jnp.asarray(RNG.normal(size=(2, 2, 128, 32)), jnp.float32) * 0.4
    o_pallas, _ = flash_mha_fwd(q, k, v, causal=True, bq=32, bk=32)
    o_xla = ops.flash_attention(q, k, v, True, 32, "xla")
    np.testing.assert_allclose(o_pallas, o_xla, rtol=1e-4, atol=1e-4)


def test_flash_vjp_matches_oracle_grads():
    q = jnp.asarray(RNG.normal(size=(1, 4, 96, 16)), jnp.float32) * 0.4
    k = jnp.asarray(RNG.normal(size=(1, 2, 96, 16)), jnp.float32) * 0.4
    v = jnp.asarray(RNG.normal(size=(1, 2, 96, 16)), jnp.float32) * 0.4
    f = lambda q, k, v: jnp.sum(jnp.tanh(ops.flash_attention(q, k, v, True, 32, "xla")))
    g = lambda q, k, v: jnp.sum(jnp.tanh(ref.mha(q, k, v, causal=True)))
    got = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ops_merge_join_backends_agree(backend):
    l = np.sort(RNG.integers(0, 300, 2048)).astype(np.int32)
    r = np.sort(RNG.integers(0, 300, 2048)).astype(np.int32)
    got = ops.merge_join_count(jnp.asarray(l), jnp.asarray(r), 2000, 2010,
                               backend=backend)
    want = ref.merge_join_count(jnp.asarray(l), jnp.asarray(r), 2000, 2010)
    assert int(got) == int(want)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_ops_topk_backends_agree(backend):
    sc = jnp.asarray(RNG.normal(size=4096), jnp.float32)
    mask = jnp.asarray(RNG.random(4096) > 0.3)
    v, i = ops.topk(sc, mask, 4000, 5, backend=backend)
    smask = np.where(np.asarray(mask) & (np.arange(4096) < 4000),
                     np.asarray(sc), -np.inf)
    want = np.sort(smask)[::-1][:5]
    np.testing.assert_allclose(np.asarray(v), want, rtol=1e-6)
    np.testing.assert_allclose(smask[np.asarray(i)], want, rtol=1e-6)


@pytest.mark.parametrize("mode", ["gspmd", "kernel"])
def test_session_mode_matches_numpy(mode):
    """Engine-level sweep: the same queries through either execution mode
    agree with the numpy oracle (the kernel mode rides the ops above)."""
    from repro.core.frame import AFrame
    from repro.data import wisconsin
    from repro.engine.session import Session

    t = wisconsin.generate(3_000, seed=9)
    raw = {k: np.asarray(v) for k, v in t.columns.items()}
    sess = Session(mode=mode)
    sess.create_dataset("data", t, dataverse="m", closed=True)
    df = AFrame("m", "data", session=sess)
    df_r = AFrame("m", "data", session=sess)

    n = len(df[(df["ten"] == 6) & (df["two"] == 0)])
    assert n == int(((raw["ten"] == 6) & (raw["two"] == 0)).sum())
    g = df.groupby("four").agg("count")
    np.testing.assert_array_equal(
        g["count"], [int((raw["four"] == v).sum()) for v in range(4)])
    h = df.sort_values("unique1", ascending=False).head(5)
    np.testing.assert_array_equal(h["unique1"], np.sort(raw["unique1"])[::-1][:5])
    assert len(df.merge(df_r, left_on="unique1", right_on="unique1")) == 3_000


@pytest.mark.parametrize("B,H,KV,S,D,bk", [(2, 4, 2, 256, 32, 64),
                                           (1, 8, 8, 128, 64, 128),
                                           (3, 6, 2, 512, 16, 256)])
def test_flash_decode_sweep(B, H, KV, S, D, bk):
    q = jnp.asarray(RNG.normal(size=(B, H, D)), jnp.float32) * 0.4
    k = jnp.asarray(RNG.normal(size=(B, KV, S, D)), jnp.float32) * 0.4
    v = jnp.asarray(RNG.normal(size=(B, KV, S, D)), jnp.float32) * 0.4
    lens = jnp.asarray(RNG.integers(1, S, B), jnp.int32)
    got = flash_decode(q, k, v, lens, bk=bk)
    want = ref.decode_attention(q, k, v, lens)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
