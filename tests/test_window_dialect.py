"""Window functions + dialect rendering (paper §VI future work, implemented)."""
import numpy as np
import pytest

from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine.session import Session
from repro.engine.table import Table


@pytest.fixture(scope="module")
def sess():
    s = Session()
    s.create_dataset("D", wisconsin.generate(5_000, seed=5), dataverse="w",
                     indexes=["onePercent"])
    return s


def _df(sess):
    return AFrame("w", "D", session=sess)


def test_row_number_global(sess):
    df = _df(sess).window(order_by="unique1").row_number()
    out = df.collect()
    order = np.argsort(out["unique1"])
    assert list(out["row_number"][order]) == list(range(1, 5_001))


def test_row_number_partitioned(sess):
    df = _df(sess).window(order_by="unique1", partition_by="ten").row_number("rn")
    out = df.collect()
    for t in range(10):
        grp = out["rn"][out["ten"] == t]
        assert sorted(grp) == list(range(1, len(grp) + 1))
    # smallest unique1 in each partition has rn == 1
    for t in range(3):
        m = out["ten"] == t
        i = np.argmin(out["unique1"][m])
        assert out["rn"][m][i] == 1


def test_rank_with_ties(sess):
    # rank over 'two' (ties everywhere): rank jumps by tie-group size
    df = _df(sess).window(order_by="two").rank("r")
    out = df.collect()
    zeros = (out["two"] == 0).sum()
    assert set(out["r"][out["two"] == 0]) == {1}
    assert set(out["r"][out["two"] == 1]) == {zeros + 1}


def test_cumsum_partitioned(sess):
    df = _df(sess).window(order_by="unique1", partition_by="four").cumsum("two")
    out = df.collect()
    for p in range(4):
        m = out["four"] == p
        order = np.argsort(out["unique1"][m])
        want = np.cumsum(out["two"][m][order])
        np.testing.assert_allclose(out["cumsum_two"][m][order], want, rtol=1e-5)


def test_moving_avg(sess):
    df = _df(sess).window(order_by="unique2").moving_avg("unique1", 4)
    out = df.collect()
    order = np.argsort(out["unique2"])
    v = out["unique1"][order].astype(np.float64)
    got = out["mavg4_unique1"][order]
    for i in (0, 1, 5, 100):
        lo = max(0, i - 3)
        np.testing.assert_allclose(got[i], v[lo:i + 1].mean(), rtol=1e-5)


def test_window_sql_rendering(sess):
    df = _df(sess).window(order_by="unique1", partition_by="ten").row_number()
    q = df.query
    assert "ROW_NUMBER() OVER (PARTITION BY t.ten ORDER BY t.unique1)" in q


def test_window_over_filter(sess):
    base = _df(sess)
    df = base[base["two"] == 0].window(order_by="unique1").row_number("rn")
    out = df.collect()
    assert len(out["rn"]) == (np.asarray(
        sess.catalog.get("w", "D").table.columns["two"]) == 0).sum()
    assert sorted(out["rn"]) == list(range(1, len(out["rn"]) + 1))


# -- dialect ----------------------------------------------------------------------


def test_postgres_dialect_basic(sess):
    df = _df(sess)
    q = df[df["coordinate"].notna()].query_in("postgres") \
        if "coordinate" in [] else None
    d = df[df["ten"] == 3][["two", "four"]]
    pg = d.query_in("postgres")
    assert pg.startswith("SELECT")
    assert "SELECT VALUE" not in pg
    assert "w.d" in pg  # lowercased schema.table
    assert "t.ten = 3" in pg


def test_postgres_is_not_null(sess):
    df = _df(sess)
    f = df[df["unique1"].notna()]
    pg = f.query_in("postgres")
    assert "IS NOT NULL" in pg and "IS KNOWN" not in pg
    assert "IS KNOWN" in f.query  # sqlpp unchanged


def test_postgres_groupby_join(sess):
    from repro.core import plan as P

    df = _df(sess)
    g = P.GroupAgg(df._plan, ["twenty"], [P.AggSpec("c", "count", None)])
    from repro.core.dialect import render

    pg = render(g, "postgres")
    assert "GROUP BY t.twenty" in pg and "COUNT(*) AS c" in pg
    j = P.JoinCount(df._plan, df._plan, "unique1", "unique1")
    pg = render(j, "postgres")
    assert "JOIN" in pg and "COUNT(*)" in pg


def test_dialect_roundtrip_same_semantics(sess):
    """The IR is dialect-independent: results come from the engine, the
    rendered text is just the paper's §VI 'language module' output."""
    df = _df(sess)
    n = len(df[(df["onePercent"] >= 5) & (df["onePercent"] <= 9)])
    raw = np.asarray(sess.catalog.get("w", "D").table.columns["onePercent"])
    assert n == int(((raw >= 5) & (raw <= 9)).sum())
