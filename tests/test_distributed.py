"""Multi-device behaviour via subprocesses (jax locks the host device count
at first init, so these spawn fresh interpreters with forced device counts —
the main pytest process stays single-device)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def run_script(body: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_dataframe_shard_map_equivalence():
    run_script("""
import numpy as np
from repro.data import wisconsin
from repro.engine.session import Session
from repro.core.frame import AFrame
from repro.launch.mesh import make_local_mesh

t = wisconsin.generate(10_000, seed=1)
raw = {k: np.asarray(v) for k, v in t.columns.items()}
mesh = make_local_mesh(data=8, model=1)
sess = Session(mesh=mesh, mode="shard_map")
sess.create_dataset("Data", t, dataverse="demo", indexes=["onePercent", "unique1"], primary="unique2")
df = AFrame("demo", "Data", session=sess)
assert len(df) == 10_000
n = len(df[(df["ten"] == 3) & (df["twentyPercent"] == 2) & (df["two"] == 1)])
assert n == int(((raw["ten"]==3)&(raw["twentyPercent"]==2)&(raw["two"]==1)).sum())
assert df["unique1"].max() == raw["unique1"].max()
g = df.groupby("oddOnePercent").agg("count")
assert g["count"].sum() == 10_000 and len(g["count"]) == 100
sh = df.sort_values("unique1", ascending=False).head(5)
assert list(sh["unique1"]) == sorted(raw["unique1"])[-5:][::-1]
n = len(df[(df["onePercent"] >= 10) & (df["onePercent"] <= 30)])
assert n == int(((raw["onePercent"]>=10)&(raw["onePercent"]<=30)).sum())
df2 = AFrame("demo", "Data", session=sess)
assert len(df.merge(df2, left_on="unique1", right_on="unique1")) == 10_000
print("OK")
""")


def test_dataframe_kernel_mode_sharded_equivalence():
    """mode="kernel" over an 8-shard mesh: each shard runs the relational
    kernels locally, partials merge with the minimal collectives."""
    run_script("""
import numpy as np
from repro.data import wisconsin
from repro.engine.session import Session
from repro.core.frame import AFrame
from repro.launch.mesh import make_local_mesh

t = wisconsin.generate(10_000, seed=1)
raw = {k: np.asarray(v) for k, v in t.columns.items()}
mesh = make_local_mesh(data=8, model=1)
sess = Session(mesh=mesh, mode="kernel")
sess.create_dataset("Data", t, dataverse="demo")
df = AFrame("demo", "Data", session=sess)
n = len(df[(df["ten"] == 3) & (df["twentyPercent"] == 3) & (df["two"] == 1)])
assert n == int(((raw["ten"]==3)&(raw["twentyPercent"]==3)&(raw["two"]==1)).sum()), n
g = df.groupby("oddOnePercent").agg("count")
assert g["count"].sum() == 10_000 and len(g["count"]) == 100
sh = df.sort_values("unique1", ascending=False).head(5)
assert list(sh["unique1"]) == sorted(raw["unique1"])[-5:][::-1]
n = len(df[(df["onePercent"] >= 10) & (df["onePercent"] <= 30)])
assert n == int(((raw["onePercent"]>=10)&(raw["onePercent"]<=30)).sum())
df2 = AFrame("demo", "Data", session=sess)
assert len(df.merge(df2, left_on="unique1", right_on="unique1")) == 10_000
from repro.kernels import ops
assert ops.DISPATCH_COUNTS.get("filter_count", 0) >= 1
assert ops.DISPATCH_COUNTS.get("segment_agg", 0) >= 1
assert ops.DISPATCH_COUNTS.get("topk", 0) >= 1
assert ops.DISPATCH_COUNTS.get("merge_join_count", 0) >= 1
print("OK")
""")


def test_hash_repartition_join():
    run_script("""
import numpy as np, jax.numpy as jnp
from repro.data import wisconsin
from repro.engine import distributed as D
from repro.engine.session import Session
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(data=8, model=1)
sess = Session(mesh=mesh, mode="shard_map")
t = wisconsin.generate(8_000, seed=2)
sess.create_dataset("Data", t, dataverse="d")
ds = sess.catalog.get("d", "Data")
k = ds.table.columns["unique1"]; m = ds.table.valid
total, drops = D.hash_repartition_counts(mesh, ("data",), k, m, k, m)
assert int(total) == 8_000 and int(drops) == 0, (int(total), int(drops))
# duplicate keys: ten has 800 of each value -> 800^2 * 10 pairs
k2 = ds.table.columns["ten"]
total2, drops2 = D.hash_repartition_counts(mesh, ("data",), k2, m, k2, m,
                                           capacity_factor=12.0)
want = sum(int((np.asarray(k2)==v).sum())**2 for v in range(10))
assert int(total2) == want, (int(total2), want)
print("OK")
""")


def test_train_step_dp_equivalence():
    """Same batch, 1 device vs 8-way DP mesh: identical loss."""
    run_script("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.launch.mesh import make_local_mesh, MeshAxes
from repro.models import registry
from repro.models.optim import OptimConfig, init_opt_state
from repro.models.sharding import sharding_ctx, param_shardings
from repro.models.steps import init_train_state, make_train_step

cfg = get_config("qwen3-1.7b").reduced()
api = registry.get_api(cfg)
params, opt = init_train_state(jax.random.key(0), cfg, api)
batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab)}
step = make_train_step(cfg, OptimConfig(total_steps=10), api)
_,_, m1 = jax.jit(step)(params, opt, batch)

mesh = make_local_mesh(data=4, model=2)
axes = MeshAxes.for_mesh(mesh)
shards = param_shardings(params, mesh, axes)
params_s = jax.device_put(params, shards)
opt_s = init_opt_state(params_s)
batch_s = {"tokens": jax.device_put(batch["tokens"], NamedSharding(mesh, P("data", None)))}
with sharding_ctx(mesh, axes):
    _,_, m2 = jax.jit(step)(params_s, opt_s, batch_s)
d = abs(float(m1["loss"]) - float(m2["loss"]))
assert d < 5e-3, (float(m1["loss"]), float(m2["loss"]))
print("OK", float(m1["loss"]), float(m2["loss"]))
""")


def test_moe_ep_shard_map_equivalence():
    """MoE layer: 1-device local dispatch == 4-way EP shard_map."""
    run_script("""
import numpy as np, jax, jax.numpy as jnp
from repro.launch.mesh import make_local_mesh, MeshAxes
from repro.models.config import ArchConfig, MoESpec
from repro.models.moe import init_moe, moe_ffn
from repro.models.sharding import sharding_ctx

cfg = ArchConfig(name="m", family="moe", n_layers=1, d_model=32, n_heads=2,
                 n_kv_heads=2, d_ff=64, vocab=64, d_head=16,
                 moe=MoESpec(num_experts=8, top_k=2, num_shared=1,
                             d_ff_expert=16, capacity_factor=16.0))
p = init_moe(jax.random.key(0), cfg, cfg.moe)
x = jax.random.normal(jax.random.key(1), (4, 8, 32), jnp.float32)
y1, aux1 = moe_ffn(x, p, cfg, cfg.moe)  # no ctx: local path
mesh = make_local_mesh(data=2, model=4)
with sharding_ctx(mesh, MeshAxes.for_mesh(mesh)):
    y2, aux2 = jax.jit(lambda x, p: moe_ffn(x, p, cfg, cfg.moe))(x, p)
np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
print("OK")
""", devices=8)


def test_elastic_checkpoint_reshard():
    """Save on a 4-shard layout, restore onto an 8-shard mesh."""
    run_script("""
import tempfile, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_local_mesh
from repro.runtime.checkpoint import CheckpointManager

with tempfile.TemporaryDirectory() as d:
    mesh4 = make_local_mesh(4, 1)
    w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                       NamedSharding(mesh4, P("data", None)))
    cm = CheckpointManager(d, async_save=False)
    cm.save(1, {"w": w})
    mesh8 = make_local_mesh(8, 1)
    sh = {"w": NamedSharding(mesh8, P("data", None))}
    _, t = cm.restore(None, {"w": w}, shardings=sh)
    assert t["w"].sharding.mesh.shape["data"] == 8
    np.testing.assert_allclose(np.asarray(t["w"]), np.arange(64.0).reshape(8, 8))
print("OK")
""")


def test_shardmap_decode_matches_baseline():
    """§Perf C4: the explicit shard_map decode (rank-local 1-token cache
    write + psum online softmax) matches the GSPMD one-hot baseline."""
    run_script("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_local_mesh, MeshAxes
from repro.models.registry import get_api
from repro.models.sharding import sharding_ctx

cfg0 = get_config("qwen3-1.7b").reduced()
api = get_api(cfg0)
params = api.init(jax.random.key(0), cfg0)
toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg0.vocab)
cache, _ = api.prefill(params, {"tokens": toks}, cfg0, 20)
new = jnp.ones((2, 1), jnp.int32)
c1, l1 = api.decode(params, cache, new, cfg0)
mesh = make_local_mesh(data=2, model=2)
cfg2 = dataclasses.replace(cfg0, decode_cache_update="shardmap")
with sharding_ctx(mesh, MeshAxes.for_mesh(mesh)):
    c2, l2 = jax.jit(lambda p, c, t: api.decode(p, c, t, cfg2))(params, cache, new)
assert float(jnp.max(jnp.abs(l1 - l2))) < 8e-2
assert (jnp.argmax(l1[:, -1], -1) == jnp.argmax(l2[:, -1], -1)).all()
np.testing.assert_allclose(np.asarray(c1["k"]), np.asarray(c2["k"]), atol=0.06)
print("OK")
""", devices=4)


def test_compressed_psum_shard_map():
    run_script("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_local_mesh
from repro.runtime.compress import compressed_psum, init_error_state
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map

mesh = make_local_mesh(8, 1)
g_local = np.random.default_rng(0).normal(size=(8, 128)).astype(np.float32)

def f(g):
    err = init_error_state({"w": g})
    mean, _ = compressed_psum({"w": g}, err, "data")
    return mean["w"]

out = shard_map(f, mesh=mesh, in_specs=P("data", None), out_specs=P("data", None))(
    jnp.asarray(g_local))
want = g_local.mean(axis=0)
got = np.asarray(out)[0]
assert np.abs(got - want).max() < 0.02, np.abs(got - want).max()
print("OK")
""")
