"""Hypothesis property tests on the system's invariants.

Core invariant: the optimizer NEVER changes results — for random data and
random predicate trees, (optimized plan) == (unoptimized plan) == numpy
oracle, with and without indexes.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the optional "
                    "hypothesis dependency")
from hypothesis import given, settings, strategies as st

from repro.core import plan as P
from repro.core.expr import BoolOp, Col, Compare, Expr, Lit, Not
from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine.session import Session

COLS = ["two", "four", "ten", "twenty", "onePercent", "twentyPercent"]
DOMAIN = {"two": 2, "four": 4, "ten": 10, "twenty": 20, "onePercent": 100,
          "twentyPercent": 5}
OPS = ["==", "!=", "<", "<=", ">", ">="]

N_ROWS = 2_000


def _sessions():
    t = wisconsin.generate(N_ROWS, seed=7)
    raw = {k: np.asarray(v) for k, v in t.columns.items()}
    s_plain = Session(enable_index=False, enable_pushdown=False)
    s_plain.create_dataset("D", t, dataverse="p")
    s_opt = Session()
    s_opt.create_dataset("D", t, dataverse="p",
                         indexes=["onePercent", "ten"], primary="unique2")
    return raw, s_plain, s_opt


RAW, S_PLAIN, S_OPT = _sessions()


@st.composite
def predicates(draw, depth=0) -> tuple:
    """Returns (Expr builder fn, numpy evaluator fn)."""
    if depth < 2 and draw(st.booleans()):
        op = draw(st.sampled_from(["AND", "OR", "NOT"]))
        l_e, l_np = draw(predicates(depth=depth + 1))
        if op == "NOT":
            return (lambda: Not(l_e()), lambda r: ~l_np(r))
        r_e, r_np = draw(predicates(depth=depth + 1))
        if op == "AND":
            return (lambda: BoolOp("AND", l_e(), r_e()),
                    lambda r: l_np(r) & r_np(r))
        return (lambda: BoolOp("OR", l_e(), r_e()),
                lambda r: l_np(r) | r_np(r))
    col = draw(st.sampled_from(COLS))
    op = draw(st.sampled_from(OPS))
    val = draw(st.integers(min_value=-1, max_value=DOMAIN[col]))
    np_ops = {"==": np.equal, "!=": np.not_equal, "<": np.less,
              "<=": np.less_equal, ">": np.greater, ">=": np.greater_equal}
    return (lambda: Compare(op, Col(col), Lit(val)),
            lambda r: np_ops[op](r[col], val))


@settings(max_examples=25, deadline=None)
@given(predicates())
def test_filter_count_optimizer_equivalence(pred):
    make_expr, np_eval = pred
    want = int(np_eval(RAW).sum())
    for sess in (S_PLAIN, S_OPT):
        plan = P.Agg(P.Filter(P.Scan("D", "p"), make_expr()),
                     [P.AggSpec("count", "count", None)])
        got = sess.execute(plan)
        assert got == want, (sess.mode, got, want)


@settings(max_examples=10, deadline=None)
@given(predicates(), st.sampled_from(COLS), st.booleans(),
       st.integers(min_value=1, max_value=7))
def test_topk_equivalence(pred, key, ascending, k):
    make_expr, np_eval = pred
    mask = np_eval(RAW)
    vals = np.sort(RAW[key][mask])
    want = (vals[:k] if ascending else vals[::-1][:k])
    for sess in (S_PLAIN, S_OPT):
        plan = P.Limit(P.Sort(P.Filter(P.Scan("D", "p"), make_expr()),
                              key, ascending), k)
        got = sess.execute(plan)[key]
        assert list(got) == list(want), (sess.mode, got, want)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(["two", "four", "ten", "twenty"]),
       st.sampled_from(["count", "max", "min", "sum"]))
def test_groupby_equivalence(key, op):
    col = "unique1"
    aggs = [P.AggSpec("out", op, None if op == "count" else col)]
    plan = P.GroupAgg(P.Scan("D", "p"), [key], aggs)
    for sess in (S_PLAIN, S_OPT):
        got = sess.execute(plan)
        for kv, ov in zip(got[key], got["out"]):
            sel = RAW[col][RAW[key] == kv]
            want = {"count": sel.size, "max": sel.max(), "min": sel.min(),
                    "sum": sel.sum()}[op]
            assert ov == want


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=99), st.integers(min_value=0, max_value=99))
def test_range_count_index_equivalence(a, b):
    lo, hi = min(a, b), max(a, b)
    want = int(((RAW["onePercent"] >= lo) & (RAW["onePercent"] <= hi)).sum())
    pred = BoolOp("AND", Compare(">=", Col("onePercent"), Lit(lo)),
                  Compare("<=", Col("onePercent"), Lit(hi)))
    plan = P.Agg(P.Filter(P.Scan("D", "p"), pred),
                 [P.AggSpec("count", "count", None)])
    assert S_OPT.execute(plan) == want  # index-only path
    assert S_PLAIN.execute(plan) == want  # scan path


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(["unique1", "ten", "onePercent"]))
def test_join_count_equivalence(key):
    want = 0
    vals, counts = np.unique(RAW[key], return_counts=True)
    want = int((counts.astype(np.int64) ** 2).sum())
    plan = P.Agg(P.Join(P.Scan("D", "p"), P.Scan("D", "p"), key, key),
                 [P.AggSpec("count", "count", None)])
    for sess in (S_PLAIN, S_OPT):
        assert sess.execute(plan) == want
