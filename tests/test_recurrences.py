"""Chunked-vs-sequential equivalence for the linear-recurrence mixers, MoE
dispatch invariants, and attention variants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ArchConfig, MoESpec
from repro.models.moe import _capacity, _local_moe, init_moe, moe_ffn
from repro.models.rwkv import wkv6_chunked, wkv6_sequential
from repro.models.ssm import ssd_chunked, ssd_sequential

RNG = np.random.default_rng(3)


@pytest.mark.parametrize("B,S,H,N,chunk", [(2, 64, 3, 8, 16), (1, 48, 2, 16, 16),
                                           (2, 33, 1, 4, 16)])
def test_wkv6_chunked_matches_sequential(B, S, H, N, chunk):
    ks = jax.random.split(jax.random.key(0), 5)
    r = jax.random.normal(ks[0], (B, S, H, N)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, N)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, N)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.3 - 0.6)
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    o1, s1 = wkv6_chunked(r, k, v, lw, u, chunk=chunk)
    o2, s2 = wkv6_sequential(r, k, v, lw, u)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


def test_wkv6_state_carry():
    """Split sequence == full sequence (state threading)."""
    B, S, H, N = 1, 32, 2, 8
    ks = jax.random.split(jax.random.key(1), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, N)) * 0.5 for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.3 - 0.6)
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    o_full, s_full = wkv6_sequential(r, k, v, lw, u)
    o1, s1 = wkv6_sequential(r[:, :16], k[:, :16], v[:, :16], lw[:, :16], u)
    o2, s2 = wkv6_sequential(r[:, 16:], k[:, 16:], v[:, 16:], lw[:, 16:], u, state0=s1)
    np.testing.assert_allclose(jnp.concatenate([o1, o2], 1), o_full, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s2, s_full, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [(2, 64, 3, 8, 8, 16),
                                             (1, 50, 2, 16, 4, 32)])
def test_ssd_chunked_matches_sequential(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    Bc = jax.random.normal(ks[1], (B, S, N)) * 0.5
    Cc = jax.random.normal(ks[2], (B, S, N)) * 0.5
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (B, S, H)))
    o1, s1 = ssd_chunked(x, Bc, Cc, la, dt, chunk=chunk)
    o2, s2 = ssd_sequential(x, Bc, Cc, la, dt)
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


# -- MoE -------------------------------------------------------------------------


def _moe_cfg(cf=8.0):
    return ArchConfig(name="m", family="moe", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab=64, d_head=16,
                      moe=MoESpec(num_experts=4, top_k=2, num_shared=1,
                                  d_ff_expert=16, capacity_factor=cf))


def _dense_moe_oracle(x, p, spec):
    """All-experts dense computation with identical top-k gates (dropless)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = (xf @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, spec.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    w1, w3, w2 = p["experts"]["w1"], p["experts"]["w3"], p["experts"]["w2"]
    h = jnp.einsum("td,edf->tef", xf, w1)
    g = jax.nn.silu(h) * jnp.einsum("td,edf->tef", xf, w3)
    y_all = jnp.einsum("tef,efd->ted", g, w2)  # every expert for every token
    full_gate = jnp.zeros((xf.shape[0], spec.num_experts))
    full_gate = full_gate.at[jnp.arange(xf.shape[0])[:, None], idx].set(gates)
    y = jnp.einsum("te,ted->td", full_gate, y_all)
    return y.reshape(B, S, d)


def test_moe_matches_dense_oracle_when_dropless():
    cfg = _moe_cfg(cf=16.0)
    spec = cfg.moe
    p = init_moe(jax.random.key(0), cfg, spec)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model), jnp.float32)
    y, aux = _local_moe(x, p["router"], p["experts"]["w1"], p["experts"]["w3"],
                        p["experts"]["w2"], spec=spec, e_local=spec.num_experts,
                        rank=0, psum=lambda v: v, pmean=lambda v: v)
    want = _dense_moe_oracle(x, p, spec)
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_bounded():
    """With cf=0.5 some tokens drop; outputs stay finite and norm-bounded."""
    cfg = _moe_cfg(cf=0.5)
    spec = cfg.moe
    p = init_moe(jax.random.key(0), cfg, spec)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model), jnp.float32)
    y, aux = _local_moe(x, p["router"], p["experts"]["w1"], p["experts"]["w3"],
                        p["experts"]["w2"], spec=spec, e_local=spec.num_experts,
                        rank=0, psum=lambda v: v, pmean=lambda v: v)
    assert np.all(np.isfinite(np.asarray(y)))
    dropless = _dense_moe_oracle(x, p, spec)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(dropless)) * 1.5


def test_moe_ep_rank_partition_sums_to_whole():
    """Σ over ranks of partial outputs == single-rank full output (the psum
    identity the shard_map EP path relies on)."""
    cfg = _moe_cfg(cf=16.0)
    spec = cfg.moe
    p = init_moe(jax.random.key(0), cfg, spec)
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model), jnp.float32)
    full, _ = _local_moe(x, p["router"], p["experts"]["w1"], p["experts"]["w3"],
                         p["experts"]["w2"], spec=spec, e_local=4, rank=0,
                         psum=lambda v: v, pmean=lambda v: v)
    parts = []
    for r in range(2):  # 2 ranks × 2 local experts
        w1 = p["experts"]["w1"][r * 2:(r + 1) * 2]
        w3 = p["experts"]["w3"][r * 2:(r + 1) * 2]
        w2 = p["experts"]["w2"][r * 2:(r + 1) * 2]
        y, _ = _local_moe(x, p["router"], w1, w3, w2, spec=spec, e_local=2,
                          rank=r, psum=lambda v: v, pmean=lambda v: v)
        parts.append(y)
    np.testing.assert_allclose(parts[0] + parts[1], full, rtol=1e-4, atol=1e-4)


def test_capacity_floor():
    assert _capacity(2, MoESpec(num_experts=64, top_k=6)) == 4  # decode floor
