"""Streaming ingestion subsystem (engine/lsm.py): device-resident runs,
deferred compaction, base ∪ runs query equivalence in all three execution
modes, schema validation, and incrementally-maintained materialized views."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import plan as P
from repro.core.frame import AFrame
from repro.data import wisconsin
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.kernels import ops

BASE_ROWS = 3_000
PUSH_ROWS = 700

DEFERRED = lsm.CompactionPolicy(size_ratio=10.0, max_runs=64)  # never auto


def _session(mode):
    if mode == "shard_map":
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        return Session(mesh=mesh, mode="shard_map")
    return Session(mode=mode)


def _fed_session(mode, n_pushes=2):
    sess = _session(mode)
    t = wisconsin.generate(BASE_ROWS, seed=3)
    sess.create_dataset("Live", t, dataverse="d", indexes=["onePercent"],
                        primary="unique2")
    sess.create_dataset("Dim", wisconsin.generate(500, seed=7), dataverse="d")
    feed = Feed(sess, "Live", "d", flush_rows=PUSH_ROWS, policy=DEFERRED)
    for i in range(n_pushes):
        extra = wisconsin.generate(PUSH_ROWS, seed=20 + i)
        rows = {k: np.asarray(v) for k, v in extra.columns.items()}
        rows["unique2"] = rows["unique2"] + BASE_ROWS + i * PUSH_ROWS
        feed.push(rows)
    return sess, feed


def _query_suite(sess):
    """Snapshot of every query family over the fed dataset."""
    df = AFrame("d", "Live", session=sess)
    dim = AFrame("d", "Dim", session=sess)
    out = {
        "len": len(df),
        "filter_count": len(df[(df["ten"] == 3) & (df["two"] == 1)]),
        "indexed_range": len(df[(df["onePercent"] >= 10) & (df["onePercent"] <= 30)]),
        "group_count": df.groupby("ten").agg("count"),
        "group_mix": df.groupby("twenty").agg(
            {"four": "sum", "ten": "mean", "two": "max", "onePercent": "min"}),
        "scalar_max": df["unique2"].max(),
        "scalar_min": df["unique1"].min(),
        "scalar_sum": df["four"].sum(),
        "sort_head": df.sort_values("unique1", ascending=False).head(7),
        "head": df.head(5),
        "join_count": len(df.merge(dim, left_on="unique1", right_on="unique1")),
        "project_head": df[["two", "four", "stringu1"]].head(4),
    }
    return out


def _assert_same(a, b, label):
    if isinstance(a, dict):
        assert set(a) == set(b), label
        for k in a:
            av, bv = np.asarray(a[k]), np.asarray(b[k])
            assert av.dtype == bv.dtype, (label, k, av.dtype, bv.dtype)
            np.testing.assert_array_equal(av, bv, err_msg=f"{label}:{k}")
    else:
        assert a == b, (label, a, b)


@pytest.mark.parametrize("mode", ["gspmd", "shard_map", "kernel"])
def test_queries_identical_before_and_after_compaction(mode):
    """The LSM read invariant: base ∪ runs must answer every query family
    bit-identically to the compacted dataset — in all three session modes."""
    sess, feed = _fed_session(mode)
    assert feed.stats["flushes"] == 2 and feed.stats["compactions"] == 0
    before = _query_suite(sess)
    feed.compact()
    assert feed.stats["compactions"] == 1
    after = _query_suite(sess)
    for k in before:
        _assert_same(before[k], after[k], f"{mode}:{k}")


def test_union_plan_on_lowered_path():
    """Pre-compaction plans actually fan out per LSM component."""
    from repro.core import physical as PH

    sess, feed = _fed_session("gspmd")
    df = AFrame("d", "Live", session=sess)
    len(df)
    opt = sess.last_optimized
    assert isinstance(opt, P.UnionScalar)
    assert len(opt.children) == 3  # base + 2 runs
    df.sort_values("unique1").head(3)
    assert any(isinstance(n, PH.PrunedUnionRuns)
               for n in PH.walk(sess.last_physical))
    # per-component access paths: the indexed range count runs one
    # index-only probe per component (onePercent spans overlap every
    # component, so zone maps prune nothing here)
    len(df[(df["onePercent"] >= 5) & (df["onePercent"] <= 9)])
    probes = [n for n in PH.walk(sess.last_physical)
              if isinstance(n, PH.IndexOnlyCount)]
    assert len(probes) == 3
    assert {n.dataset for n in probes} == {"Live", "Live@run0", "Live@run1"}


def test_kernel_mode_launches_per_component():
    sess, feed = _fed_session("kernel")
    df = AFrame("d", "Live", session=sess)
    ops.reset_dispatch_counts()
    len(df[(df["ten"] == 2) & (df["two"] == 0)])  # fused range count
    assert ops.DISPATCH_COUNTS.get("filter_count", 0) == 3  # one per component
    ops.reset_dispatch_counts()
    df.groupby("ten").agg("count")
    assert ops.DISPATCH_COUNTS.get("segment_agg", 0) == 3


def test_group_max_min_on_kernel_path():
    """ROADMAP item: group max/min now lower onto segment_agg (select-and-
    reduce op) when catalog bounds prove f32 exactness — bit-identical to
    gspmd."""
    t = wisconsin.generate(4_000, seed=5)
    results = {}
    for mode in ("gspmd", "kernel"):
        sess = Session(mode=mode)
        sess.create_dataset("W", t, dataverse="k")
        df = AFrame("k", "W", session=sess)
        ops.reset_dispatch_counts()
        results[mode] = df.groupby("twenty").agg({"four": "max", "ten": "min"})
        if mode == "kernel":
            assert ops.DISPATCH_COUNTS.get("segment_agg", 0) >= 1
    _assert_same(results["kernel"], results["gspmd"], "group_max_min")


def test_segment_agg_max_min_pallas_matches_ref():
    rng = np.random.default_rng(0)
    n, g, c = 5_000, 13, 3
    gids = rng.integers(-1, g, n).astype(np.int32)
    vals = rng.integers(-1000, 1000, (n, c)).astype(np.float32)
    for op in ("max", "min", "sum"):
        got = np.asarray(ops.segment_agg(vals, gids, g, n - 7, op=op,
                                         backend="pallas"))
        want = np.asarray(ops.segment_agg(vals, gids, g, n - 7, op=op,
                                          backend="xla"))
        np.testing.assert_array_equal(got, want, err_msg=op)


def test_run_components_and_metadata_preserved():
    """Runs carry their own sorted indexes + zone maps; compaction preserves
    closed / primary / secondary metadata on the rebuilt base."""
    sess, feed = _fed_session("gspmd")
    ds = sess.catalog.get("d", "Live")
    assert len(ds.runs) == 2
    run = sess.catalog.get("d", "Live@run0")
    assert run is ds.runs[0]
    assert run.closed and run.live_rows == PUSH_ROWS
    assert run.table.num_rows % lsm.RUN_BLOCK == 0  # block-padded
    assert "__valid__" in run.table.columns
    # per-run secondary index + zone maps, built at flush time
    ix = run.index_on("onePercent")
    assert ix is not None and ix.kind == "secondary"
    assert ix.sorted_keys is not None and ix.zone_min is not None
    sk = np.asarray(ix.sorted_keys)
    assert np.all(np.diff(sk) >= 0)
    assert run.primary_index is not None  # run sorted by base primary
    assert run.table.meta["unique2"].sorted_ascending
    feed.compact()
    ds = sess.catalog.get("d", "Live")
    assert not ds.runs
    assert ds.closed
    assert ds.primary_index is not None and ds.primary_index.column == "unique2"
    assert ds.table.meta["unique2"].sorted_ascending
    ix = ds.index_on("onePercent")
    assert ix is not None and ix.kind == "secondary" and ix.zone_min is not None
    # merged stats stay truthful: unique2 domain covers the pushed keys
    assert ds.table.meta["unique2"].hi == BASE_ROWS + 2 * PUSH_ROWS - 1
    with pytest.raises(KeyError):
        sess.catalog.get("d", "Live@run0")


def test_group_domain_widens_with_runs():
    """A run that extends the group-key domain must not lose groups —
    neither before nor after compaction."""
    base = {"k": np.arange(8, dtype=np.int32) % 4,
            "v": np.arange(8, dtype=np.int32)}
    sess = Session()
    from repro.engine.table import Table
    sess.create_dataset("G", Table(base), dataverse="d")
    feed = Feed(sess, "G", "d", flush_rows=4, policy=DEFERRED)
    feed.push({"k": np.array([7, 7, 9, 9], np.int32),
               "v": np.array([1, 2, 3, 4], np.int32)})
    df = AFrame("d", "G", session=sess)
    before = df.groupby("k").agg("count")
    assert set(np.asarray(before["k"])) == {0, 1, 2, 3, 7, 9}
    feed.compact()
    after = AFrame("d", "G", session=sess).groupby("k").agg("count")
    _assert_same(before, after, "widened_groups")


def test_empty_flush_is_noop_and_stats_counters():
    sess, feed = _fed_session("gspmd", n_pushes=1)
    stats0 = dict(feed.stats)
    feed.flush()  # empty buffer: no-op
    assert feed.stats == stats0
    assert feed.stats["ingested"] == PUSH_ROWS
    assert feed.stats["flushes"] == 1
    assert feed.stats["runs"] == 1 and feed.stats["run_rows"] == PUSH_ROWS
    # buffering below the threshold leaves data invisible until flush
    extra = wisconsin.generate(10, seed=99)
    rows = {k: np.asarray(v) for k, v in extra.columns.items()}
    rows["unique2"] = rows["unique2"] + 10_000
    feed.push(rows)
    assert feed.stats["flushes"] == 1
    assert len(AFrame("d", "Live", session=sess)) == BASE_ROWS + PUSH_ROWS
    feed.flush()
    assert feed.stats["flushes"] == 2
    assert len(AFrame("d", "Live", session=sess)) == BASE_ROWS + PUSH_ROWS + 10
    feed.compact()
    assert feed.stats["compactions"] == 1
    assert feed.stats["runs"] == 0 and feed.stats["run_rows"] == 0


def test_compaction_policy_triggers():
    t = wisconsin.generate(1_000, seed=1)
    # size_ratio=0: the benchmark baseline — compact on every flush
    sess = Session()
    sess.create_dataset("A", t, dataverse="d")
    feed = Feed(sess, "A", "d", flush_rows=100,
                policy=lsm.CompactionPolicy(size_ratio=0.0))
    rows = {k: np.asarray(v)[:100] for k, v in t.columns.items()}
    feed.push(rows)
    assert feed.stats["flushes"] == 1 and feed.stats["compactions"] == 1
    assert not sess.catalog.get("d", "A").runs
    # max_runs cap
    sess2 = Session()
    sess2.create_dataset("B", t, dataverse="d")
    feed2 = Feed(sess2, "B", "d", flush_rows=10,
                 policy=lsm.CompactionPolicy(size_ratio=100.0, max_runs=2))
    for _ in range(3):
        feed2.push({k: np.asarray(v)[:10] for k, v in t.columns.items()})
    assert feed2.stats["flushes"] == 3
    assert feed2.stats["compactions"] == 1  # third run tripped the cap


def test_push_schema_validation():
    sess, feed = _fed_session("gspmd", n_pushes=0)
    t = wisconsin.generate(20, seed=0)
    good = {k: np.asarray(v) for k, v in t.columns.items()}

    bad = dict(good)
    del bad["ten"]
    with pytest.raises(ValueError, match="missing columns.*'ten'"):
        feed.push(bad)

    bad = dict(good)
    bad["bogus"] = np.zeros(20, np.int32)
    with pytest.raises(ValueError, match="unexpected columns.*'bogus'"):
        feed.push(bad)

    bad = dict(good)
    bad["ten"] = bad["ten"][:5]
    with pytest.raises(ValueError, match="ragged"):
        feed.push(bad)

    bad = dict(good)
    bad["ten"] = bad["ten"].astype(np.float64)
    with pytest.raises(ValueError, match="not safely castable"):
        feed.push(bad)

    bad = dict(good)
    bad["stringu1"] = bad["stringu1"][:, :8]
    with pytest.raises(ValueError, match="fixed width"):
        feed.push(bad)

    bad = dict(good)
    bad["stringu1"] = np.zeros(20, np.int32)
    with pytest.raises(ValueError, match="expected 2-d"):
        feed.push(bad)

    bad = dict(good)
    bad["unique2"] = np.full(20, 2**31 + 5, dtype=np.int64)  # wraps in int32
    with pytest.raises(ValueError, match="lossy narrowing"):
        feed.push(bad)

    assert feed.stats["ingested"] == 0  # nothing slipped through
    # in-range int64 -> int32 narrowing round-trips and must be accepted
    ok = dict(good)
    ok["ten"] = ok["ten"].astype(np.int64)
    ok["unique2"] = good["unique2"] + 50_000
    feed.push(ok)
    assert feed.stats["ingested"] == 20


def test_compaction_keeps_join_guard_for_duplicated_keys():
    """Compaction-time stat merging must not certify a key duplicated across
    components as unique: the materializing join has to keep refusing, while
    join COUNT stays exact (regression: distinct=sum saturating at rows)."""
    from repro.engine.table import Table

    k = np.arange(100, dtype=np.int32)
    sess = Session()
    sess.create_dataset("R", Table({"k": k, "v": k * 2}), dataverse="d")
    sess.create_dataset("L", Table({"k": k.copy(), "w": k * 3}), dataverse="d")
    feed = Feed(sess, "R", "d", flush_rows=100, policy=DEFERRED)
    feed.push({"k": k.copy(), "v": k * 5})  # the same keys again
    feed.compact()
    dl = AFrame("d", "L", session=sess)
    dr = AFrame("d", "R", session=sess)
    with pytest.raises(NotImplementedError, match="non-unique key"):
        dl.merge(dr, left_on="k", right_on="k").head(200)
    assert len(dl.merge(dr, left_on="k", right_on="k")) == 200  # count path


def test_group_domain_ignores_other_datasets_same_named_column():
    """A join build side carrying an unrelated huge-bounded column with the
    group key's NAME must not widen the bounded group domain (regression:
    cross-dataset lo/hi merging exploding G)."""
    from repro.engine.table import ColumnMeta, Table

    n = 400
    probe = Table({"key": (np.arange(n) % 50).astype(np.int32),
                   "u": np.arange(n, dtype=np.int32)},
                  {"key": ColumnMeta(np.dtype(np.int32), 0, 49, 50),
                   "u": ColumnMeta(np.dtype(np.int32), 0, n - 1, n)})
    build = Table({"u": np.arange(n, dtype=np.int32),
                   "key": np.arange(n, dtype=np.int32) * 1_000_000},
                  {"u": ColumnMeta(np.dtype(np.int32), 0, n - 1, n),
                   "key": ColumnMeta(np.dtype(np.int32), 0, (n - 1) * 1_000_000, n)})
    sess = Session()
    sess.create_dataset("P", probe, dataverse="d")
    sess.create_dataset("B", build, dataverse="d")
    g = AFrame("d", "P", session=sess).merge(
        AFrame("d", "B", session=sess), left_on="u", right_on="u") \
        .groupby("key").agg("count")
    assert len(np.asarray(g["key"])) == 50  # probe-side domain, not 4e8 groups


def test_view_incremental_equals_recompute():
    sess, feed = _fed_session("gspmd", n_pushes=0)
    df = AFrame("d", "Live", session=sess)
    plan = P.GroupAgg(P.Scan("Live", "d"), ["ten"], [
        P.AggSpec("count", "count", None),
        P.AggSpec("sum_four", "sum", "four"),
        P.AggSpec("mean_twenty", "mean", "twenty"),
        P.AggSpec("max_onePercent", "max", "onePercent"),
        P.AggSpec("min_unique1", "min", "unique1"),
    ])
    view = sess.create_view("by_ten", plan)
    for i in range(3):
        extra = wisconsin.generate(PUSH_ROWS, seed=40 + i)
        rows = {k: np.asarray(v) for k, v in extra.columns.items()}
        rows["unique2"] = rows["unique2"] + BASE_ROWS + i * PUSH_ROWS
        feed.push(rows)
    got = sess.read_view("by_ten")
    want = sess.execute(plan)
    _assert_same(got, want, "view_vs_recompute")
    assert view.stats["refreshes"] == 4  # seed + 3 flush deltas
    assert view.stats["rows_applied"] == BASE_ROWS + 3 * PUSH_ROWS
    assert view.stats["kernel_batches"] >= 1  # exactness held: kernel path
    # compaction must not disturb the view (it is delta-maintained)
    feed.compact()
    _assert_same(sess.read_view("by_ten"), sess.execute(plan), "view_post_compact")


def test_view_with_filter_predicate():
    sess, feed = _fed_session("gspmd", n_pushes=0)
    df = AFrame("d", "Live", session=sess)
    plan = df[df["two"] == 1].groupby("ten").agg_plan(
        {"four": "sum"})  # GroupAgg over Filter(Scan), via the public API
    sess.create_view("odd_by_ten", plan)
    extra = wisconsin.generate(PUSH_ROWS, seed=50)
    rows = {k: np.asarray(v) for k, v in extra.columns.items()}
    rows["unique2"] = rows["unique2"] + BASE_ROWS
    feed.push(rows)
    got = sess.read_view("odd_by_ten")
    want = sess.execute(plan)
    _assert_same(got, want, "filtered_view")


def test_view_rejects_unsupported_plans():
    sess, _ = _fed_session("gspmd", n_pushes=0)
    df = AFrame("d", "Live", session=sess)
    with pytest.raises(ValueError, match="group-by"):
        sess.create_view("v", df._plan)  # bare scan
    with pytest.raises(ValueError, match="group-by"):
        sess.create_view("v", P.GroupAgg(P.Scan("Live", "d"), ["ten", "two"],
                                         [P.AggSpec("count", "count", None)]))


def test_view_randomized_push_sequences_match_recompute():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.engine.table import Table

    batch = st.lists(st.tuples(st.integers(0, 12), st.integers(-50, 50)),
                     min_size=1, max_size=30)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(batch, min_size=1, max_size=5), st.integers(0, 2**31 - 1))
    def run(batches, seed):
        rng = np.random.default_rng(seed)
        n0 = int(rng.integers(1, 40))
        base = {"k": rng.integers(0, 13, n0).astype(np.int32),
                "v": rng.integers(-50, 51, n0).astype(np.int32)}
        sess = Session()
        sess.create_dataset("H", Table(base), dataverse="d")
        plan = P.GroupAgg(P.Scan("H", "d"), ["k"], [
            P.AggSpec("count", "count", None),
            P.AggSpec("sum_v", "sum", "v"),
            P.AggSpec("mean_v", "mean", "v"),
            P.AggSpec("max_v", "max", "v"),
            P.AggSpec("min_v", "min", "v")])
        sess.create_view("hv", plan)
        feed = Feed(sess, "H", "d", flush_rows=1,
                    policy=lsm.CompactionPolicy(size_ratio=2.0, max_runs=3))
        all_k = [base["k"]]
        all_v = [base["v"]]
        for b in batches:
            ks = np.array([x[0] for x in b], np.int32)
            vs = np.array([x[1] for x in b], np.int32)
            feed.push({"k": ks, "v": vs})
            all_k.append(ks)
            all_v.append(vs)
        k = np.concatenate(all_k)
        v = np.concatenate(all_v)
        got = sess.read_view("hv")
        keys = np.unique(k)
        np.testing.assert_array_equal(got["k"], keys)
        for i, kk in enumerate(keys):
            sel = v[k == kk]
            assert got["count"][i] == sel.size
            assert got["sum_v"][i] == sel.sum()
            assert got["max_v"][i] == sel.max()
            assert got["min_v"][i] == sel.min()
            np.testing.assert_equal(
                got["mean_v"][i],
                np.float32(np.float32(sel.sum()) / np.float32(sel.size)))
        # the engine's own recompute agrees, whatever the compaction state
        _assert_same(got, sess.execute(plan), "hypothesis_view")
        assert len(AFrame("d", "H", session=sess)) == k.size

    run()


def test_open_dataset_feed_roundtrip():
    """Open (schema-on-read) datasets widen runs the same way the base was
    widened — queries stay consistent across flush and compaction."""
    t = wisconsin.generate(500, seed=2)
    sess = Session()
    sess.create_dataset("O", t, dataverse="d", closed=False)
    feed = Feed(sess, "O", "d", flush_rows=100, policy=DEFERRED)
    extra = wisconsin.generate(100, seed=9)
    rows = {k: np.asarray(v) for k, v in extra.columns.items()}
    rows["unique2"] = rows["unique2"] + 500
    feed.push(rows)
    df = AFrame("d", "O", session=sess)
    before = df["four"].sum()
    assert len(df) == 600
    feed.compact()
    after = AFrame("d", "O", session=sess)["four"].sum()
    assert before == after
