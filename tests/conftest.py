"""Shared fixtures. NOTE: no XLA_FLAGS / device-count manipulation here —
the dry-run launcher is the only place that forces 512 host devices; tests
run on the default single device (multi-device behaviour is exercised via
subprocess tests in test_distributed.py).
"""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def wisconsin_small():
    from repro.data import wisconsin

    t = wisconsin.generate(10_000, seed=1)
    raw = {k: np.asarray(v) for k, v in t.columns.items()}
    return t, raw


@pytest.fixture(scope="session")
def session_with_data(wisconsin_small):
    from repro.engine.session import Session

    t, raw = wisconsin_small
    sess = Session()
    sess.create_dataset("Data", t, dataverse="demo",
                        indexes=["onePercent", "unique1"], primary="unique2")
    return sess, raw
