"""Golden-output tests for the ``explain()`` renderer (core/physical.py
``format_plan``): the PrunedUnionRuns / MergeScalars pruning rationale and
the anti-matter subtraction notes are asserted line-for-line, so the
renderer is no longer untested surface.

Cost numbers inside ``[cost=...]`` brackets and ``cost=N`` notes are
normalized — the golden text pins the plan SHAPE and the rationale wording,
not the cost model's constants."""
import re

import numpy as np

from repro.core import plan as P
from repro.core.frame import AFrame
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import Table


def _normalize(text: str) -> str:
    text = re.sub(r"\[cost=[^\]]*\]", "[cost]", text)
    text = re.sub(r"cost=[\d,]+", "cost=#", text)
    text = re.sub(r"total estimated cost: [\d,]+", "total estimated cost: #",
                  text)
    return text


def _mutated_fed_session():
    """Deterministic scenario: base keys 0..1999, run0 appends 2000..2999,
    run1 deletes {100, 150} and appends 3000..3499. A count over k ∈ [0,200]
    prunes both runs' matter — but run1's tombstones must be retained."""
    sess = Session()
    n = 2000
    k = np.arange(n, dtype=np.int32)
    sess.create_dataset("Events", Table({"k": k, "v": (k * 2).astype(np.int32)}),
                        dataverse="g", primary="k")
    feed = Feed(sess, "Events", "g", flush_rows=10**9,
                policy=lsm.CompactionPolicy(size_ratio=100.0, max_runs=64))
    feed.push({"k": np.arange(2000, 3000, dtype=np.int32),
               "v": np.zeros(1000, np.int32)})
    feed.flush()
    feed.delete(np.array([100, 150], np.int32))
    feed.push({"k": np.arange(3000, 3500, dtype=np.int32),
               "v": np.zeros(500, np.int32)})
    feed.flush()
    return sess


GOLDEN_SCALAR = """\
MergeScalars [count:sum] [1 components, 2 pruned]  [cost]
· zone maps pruned 2/3 components (1,500 rows skipped)
├─ SubtractScalars [count] [anti-matter]  [cost]
│  · anti-matter subtraction: count = index-only matches − matches newer tombstones shadow — chosen over MaskCount cost=#
│  ├─ IndexOnlyCount g.Events on k [binary search]  [cost]
│  │  · index-only: sorted primary index on k
│  └─ ShadowProbeCount g.Events on k [1 anti set(s), binary search]  [cost]
│     · 2 tombstone(s) from 1 newer component(s) probe the primary index
├─ ✂ g.Events@run0 PRUNED: zone span k∈[2000, 2999] misses predicate [-∞, 200] (1000 rows skipped)
└─ ✂ g.Events@run1 PRUNED: zone span k∈[3000, 3499] misses predicate [-∞, 200] (500 rows skipped); 2 anti-matter record(s) RETAINED — they still subtract from older components
total estimated cost: #"""


GOLDEN_TABLE = """\
UnionRuns [1 components, 2 pruned]  [cost]
· zone maps pruned 2/3 components (1,500 rows skipped)
├─ IndexProbe g.Events (k ∈ [?, ?]) ⊖ anti-matter of 1 newer component(s)  [cost]
│  · index primary:k bounds the stream — 2 newer tombstone(s) subtract from the mask
├─ ✂ g.Events@run0 PRUNED: zone span k∈[2000, 2999] misses predicate [-∞, 200] (1000 rows skipped)
└─ ✂ g.Events@run1 PRUNED: zone span k∈[3000, 3499] misses predicate [-∞, 200] (500 rows skipped); 2 anti-matter record(s) RETAINED — they still subtract from older components
total estimated cost: #"""


def test_explain_golden_scalar_count_with_subtraction_and_pruning():
    sess = _mutated_fed_session()
    df = AFrame("g", "Events", session=sess)
    plan = P.Agg(df[(df["k"] >= 0) & (df["k"] <= 200)]._plan,
                 [P.AggSpec("count", "count", None)])
    assert _normalize(sess.explain(plan)) == GOLDEN_SCALAR
    # and the plan really computes the subtracted answer
    assert len(df[(df["k"] >= 0) & (df["k"] <= 200)]) == 199  # 201 − {100,150}


def test_explain_golden_table_plan_with_shadowed_probe():
    sess = _mutated_fed_session()
    df = AFrame("g", "Events", session=sess)
    text = _normalize(sess.explain(df[(df["k"] >= 0) & (df["k"] <= 200)]._plan))
    assert text == GOLDEN_TABLE


def test_explain_frame_api_matches_session_explain():
    sess = _mutated_fed_session()
    df = AFrame("g", "Events", session=sess)
    sel = df[(df["k"] >= 0) & (df["k"] <= 200)]
    assert sel.explain() == sess.explain(sel._plan)


def test_explain_no_mutation_no_subtraction_notes():
    """A clean (tombstone-free) fed dataset renders without any anti-matter
    lines — the subtraction rationale appears only when it applies."""
    sess = Session()
    k = np.arange(1000, dtype=np.int32)
    sess.create_dataset("Clean", Table({"k": k, "v": k.copy()}),
                        dataverse="g", primary="k")
    feed = Feed(sess, "Clean", "g", flush_rows=10**9,
                policy=lsm.CompactionPolicy(size_ratio=100.0, max_runs=64))
    feed.push({"k": np.arange(1000, 1500, dtype=np.int32),
               "v": np.zeros(500, np.int32)})
    feed.flush()
    df = AFrame("g", "Clean", session=sess)
    plan = P.Agg(df[(df["k"] >= 0) & (df["k"] <= 100)]._plan,
                 [P.AggSpec("count", "count", None)])
    text = sess.explain(plan)
    assert "anti-matter" not in text and "ShadowProbeCount" not in text
    assert "PRUNED" in text  # the appended run still prunes
