"""Golden-output tests for the ``explain()`` renderer (core/physical.py
``format_plan``): the PrunedUnionRuns / MergeScalars pruning rationale and
the anti-matter subtraction notes are asserted line-for-line, so the
renderer is no longer untested surface.

Cost numbers inside ``[cost=...]`` brackets and ``cost=N`` notes are
normalized — the golden text pins the plan SHAPE and the rationale wording,
not the cost model's constants."""
import re

import numpy as np

from repro.core import plan as P
from repro.core.frame import AFrame
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import Table


def _normalize(text: str) -> str:
    text = re.sub(r"\[cost=[^\]]*\]", "[cost]", text)
    text = re.sub(r"cost=[\d,]+", "cost=#", text)
    text = re.sub(r"total estimated cost: [\d,]+", "total estimated cost: #",
                  text)
    return text


def _mutated_fed_session(mode: str = "gspmd"):
    """Deterministic scenario: base keys 0..1999, run0 appends 2000..2999,
    run1 deletes {100, 150} and appends 3000..3499. A count over k ∈ [0,200]
    prunes both runs' matter — but run1's tombstones must be retained."""
    sess = Session(mode=mode)
    n = 2000
    k = np.arange(n, dtype=np.int32)
    sess.create_dataset("Events", Table({"k": k, "v": (k * 2).astype(np.int32)}),
                        dataverse="g", primary="k")
    feed = Feed(sess, "Events", "g", flush_rows=10**9,
                policy=lsm.CompactionPolicy(size_ratio=100.0, max_runs=64))
    feed.push({"k": np.arange(2000, 3000, dtype=np.int32),
               "v": np.zeros(1000, np.int32)})
    feed.flush()
    feed.delete(np.array([100, 150], np.int32))
    feed.push({"k": np.arange(3000, 3500, dtype=np.int32),
               "v": np.zeros(500, np.int32)})
    feed.flush()
    return sess


GOLDEN_SCALAR = """\
MergeScalars [count:sum] [1 components, 2 pruned]  [cost]
· zone maps pruned 2/3 components (1,500 rows skipped)
├─ SubtractScalars [count] [anti-matter]  [cost]
│  · anti-matter subtraction: count = index-only matches − matches newer tombstones shadow — chosen over MaskCount cost=#
│  ├─ IndexOnlyCount g.Events on k [binary search]  [cost]
│  │  · index-only: sorted primary index on k
│  └─ ShadowProbeCount g.Events on k [1 anti set(s), binary search]  [cost]
│     · 2 tombstone(s) from 1 newer component(s) probe the primary index
├─ ✂ g.Events@run0 PRUNED: zone span k∈[2000, 2999] misses predicate [-∞, 200] (1000 rows skipped)
└─ ✂ g.Events@run1 PRUNED: zone span k∈[3000, 3499] misses predicate [-∞, 200] (500 rows skipped); 2 anti-matter record(s) RETAINED — they still subtract from older components
total estimated cost: #"""


GOLDEN_TABLE = """\
UnionRuns [1 components, 2 pruned]  [cost]
· zone maps pruned 2/3 components (1,500 rows skipped)
├─ IndexProbe g.Events (k ∈ [?, ?]) ⊖ anti-matter of 1 newer component(s)  [cost]
│  · index primary:k bounds the stream — 2 newer tombstone(s) subtract from the mask
├─ ✂ g.Events@run0 PRUNED: zone span k∈[2000, 2999] misses predicate [-∞, 200] (1000 rows skipped)
└─ ✂ g.Events@run1 PRUNED: zone span k∈[3000, 3499] misses predicate [-∞, 200] (500 rows skipped); 2 anti-matter record(s) RETAINED — they still subtract from older components
total estimated cost: #"""


def test_explain_golden_scalar_count_with_subtraction_and_pruning():
    sess = _mutated_fed_session()
    df = AFrame("g", "Events", session=sess)
    plan = P.Agg(df[(df["k"] >= 0) & (df["k"] <= 200)]._plan,
                 [P.AggSpec("count", "count", None)])
    assert _normalize(sess.explain(plan)) == GOLDEN_SCALAR
    # and the plan really computes the subtracted answer
    assert len(df[(df["k"] >= 0) & (df["k"] <= 200)]) == 199  # 201 − {100,150}


def test_explain_golden_table_plan_with_shadowed_probe():
    sess = _mutated_fed_session()
    df = AFrame("g", "Events", session=sess)
    text = _normalize(sess.explain(df[(df["k"] >= 0) & (df["k"] <= 200)]._plan))
    assert text == GOLDEN_TABLE


def test_explain_frame_api_matches_session_explain():
    sess = _mutated_fed_session()
    df = AFrame("g", "Events", session=sess)
    sel = df[(df["k"] >= 0) & (df["k"] <= 200)]
    assert sel.explain() == sess.explain(sel._plan)


def test_explain_no_mutation_no_subtraction_notes():
    """A clean (tombstone-free) fed dataset renders without any anti-matter
    lines — the subtraction rationale appears only when it applies."""
    sess = Session()
    k = np.arange(1000, dtype=np.int32)
    sess.create_dataset("Clean", Table({"k": k, "v": k.copy()}),
                        dataverse="g", primary="k")
    feed = Feed(sess, "Clean", "g", flush_rows=10**9,
                policy=lsm.CompactionPolicy(size_ratio=100.0, max_runs=64))
    feed.push({"k": np.arange(1000, 1500, dtype=np.int32),
               "v": np.zeros(500, np.int32)})
    feed.flush()
    df = AFrame("g", "Clean", session=sess)
    plan = P.Agg(df[(df["k"] >= 0) & (df["k"] <= 100)]._plan,
                 [P.AggSpec("count", "count", None)])
    text = sess.explain(plan)
    assert "anti-matter" not in text and "ShadowProbeCount" not in text
    assert "PRUNED" in text  # the appended run still prunes


# -- explain(analyze=True) ----------------------------------------------------


def _normalize_analyze(text: str) -> str:
    """Pin structure and actual-row counts; scrub every measured time."""
    text = re.sub(r"self=\d+\.\d\dms", "self=#", text)
    text = re.sub(r"total=\d+\.\d\dms", "total=#", text)
    text = re.sub(r"cost=[\d,]+ rows≈[\d,]+( touched=[\d,]+)?", "cost", text)
    text = re.sub(r"cost=[\d,]+", "cost=#", text)
    text = re.sub(r"total estimated cost: [\d,]+", "total estimated cost: #",
                  text)
    text = re.sub(r"measured wall time \(per-operator, unjitted\): "
                  r"\d+\.\d\dms", "measured wall time: #", text)
    text = re.sub(r"jitted end-to-end: \d+\.\d\dms", "jitted end-to-end: #",
                  text)
    return text


GOLDEN_ANALYZE_TABLE = """\
UnionRuns [1 components, 2 pruned]  [cost | self=# total=# rows=199]
· zone maps pruned 2/3 components (1,500 rows skipped)
├─ IndexProbe g.Events (k ∈ [?, ?]) ⊖ anti-matter of 1 newer component(s)  [cost | self=# total=# rows=199]
│  · index primary:k bounds the stream — 2 newer tombstone(s) subtract from the mask
├─ ✂ g.Events@run0 PRUNED: zone span k∈[2000, 2999] misses predicate [-∞, 200] (1000 rows skipped)
└─ ✂ g.Events@run1 PRUNED: zone span k∈[3000, 3499] misses predicate [-∞, 200] (500 rows skipped); 2 anti-matter record(s) RETAINED — they still subtract from older components
total estimated cost: #
measured wall time: #
jitted end-to-end: #"""


def test_explain_analyze_golden_table():
    """Golden analyze rendering: stable fields survive normalization, the
    measured row counts are exact (199 = 201 − 2 tombstoned keys), and the
    two measured-time trailer lines are present."""
    sess = _mutated_fed_session()
    df = AFrame("g", "Events", session=sess)
    text = df[(df["k"] >= 0) & (df["k"] <= 200)].explain(analyze=True)
    assert _normalize_analyze(text) == GOLDEN_ANALYZE_TABLE


def test_explain_analyze_all_modes():
    """analyze=True renders measured per-operator time + actual rows beside
    the cost estimates in all three execution modes, and the actual rows
    match the executed result."""
    for mode in ("gspmd", "shard_map", "kernel"):
        sess = _mutated_fed_session(mode=mode)
        df = AFrame("g", "Events", session=sess)
        sel = df[(df["k"] >= 0) & (df["k"] <= 200)]
        prof = sel.profile()
        assert len(prof["result"]["k"]) == 199, mode
        text = prof["text"]
        # every operator line carries measured fields beside the estimates
        op_lines = [l for l in text.splitlines()
                    if "cost=" in l and "rows≈" in l]
        assert op_lines, mode
        for line in op_lines:
            assert "self=" in line and "total=" in line and "rows=" in line, \
                (mode, line)
        assert "rows=199" in text, mode
        assert "measured wall time" in text and "jitted end-to-end" in text
        # scalar path too: count under analyze matches execution
        plan = P.Agg(sel._plan, [P.AggSpec("count", "count", None)])
        sprof = sess.profile(plan)
        assert sprof["result"] == 199, mode
        assert "rows=1" in sprof["text"], mode
        assert sess.explain(plan, analyze=True).count("self=") >= 1


def test_profile_result_matches_execute():
    sess = _mutated_fed_session()
    df = AFrame("g", "Events", session=sess)
    sel = df[(df["k"] >= 0) & (df["k"] <= 200)]
    prof = sel.profile()
    executed = sess.execute(sel._plan)
    assert set(prof["result"]) == set(executed)
    for c in executed:
        np.testing.assert_array_equal(prof["result"][c], executed[c])
