"""End-to-end AFrame behaviour: all 12 paper benchmark expressions vs a
numpy oracle (paper Table I), plus persist/setitem (Fig. 6)."""
import numpy as np
import pytest

from repro.core.frame import AFrame
from repro.engine.table import decode_strings


@pytest.fixture()
def df(session_with_data):
    sess, raw = session_with_data
    return AFrame("demo", "Data", session=sess), raw


def test_exp1_total_count(df):
    d, raw = df
    assert len(d) == len(raw["unique1"])


def test_exp2_project_head(df):
    d, raw = df
    h = d[["two", "four"]].head()
    assert set(h) == {"two", "four"} and len(h["two"]) == 5


def test_exp3_filter_count(df):
    d, raw = df
    n = len(d[(d["ten"] == 3) & (d["twentyPercent"] == 2) & (d["two"] == 1)])
    ref = int(((raw["ten"] == 3) & (raw["twentyPercent"] == 2) & (raw["two"] == 1)).sum())
    assert n == ref


def test_exp4_group_count(df):
    d, raw = df
    g = d.groupby("oddOnePercent").agg("count")
    assert g["count"].sum() == len(raw["unique1"])
    assert len(g["count"]) == 100
    k = int(g["oddOnePercent"][7])
    assert g["count"][7] == (raw["oddOnePercent"] == k).sum()


def test_exp5_map_upper_head(df):
    d, raw = df
    up = d["stringu1"].map(str.upper).head(3)
    s = decode_strings(up["stringu1"])
    assert len(s) == 3 and all(x == x.upper() for x in s)


def test_exp6_max(df):
    d, raw = df
    assert d["unique1"].max() == raw["unique1"].max()


def test_exp7_min(df):
    d, raw = df
    assert d["unique1"].min() == raw["unique1"].min()


def test_exp8_group_max(df):
    d, raw = df
    g = d.groupby("twenty")["four"].agg("max")
    for k, v in zip(g["twenty"], g["max_four"]):
        assert v == raw["four"][raw["twenty"] == k].max()


def test_exp9_sort_head(df):
    d, raw = df
    sh = d.sort_values("unique1", ascending=False).head(5)
    assert list(sh["unique1"]) == sorted(raw["unique1"])[-5:][::-1]


def test_exp10_selection_head(df):
    d, raw = df
    sel = d[d["ten"] == 4].head(5)
    assert all(sel["ten"] == 4) and len(sel["ten"]) == 5


def test_exp11_range_count(df):
    d, raw = df
    n = len(d[(d["onePercent"] >= 10) & (d["onePercent"] <= 30)])
    assert n == int(((raw["onePercent"] >= 10) & (raw["onePercent"] <= 30)).sum())


def test_exp12_join_count(df):
    d, raw = df
    d2 = AFrame("demo", "Data", session=d._session)
    assert len(d.merge(d2, left_on="unique1", right_on="unique1")) == len(raw["unique1"])


def test_mean_describe(df):
    d, raw = df
    assert abs(d["unique1"].mean() - raw["unique1"].mean()) < 0.5


def test_setitem_and_persist(df):
    d, raw = df
    sub = d[d["two"] == 0][["unique1", "ten"]]
    sub["ten_sq"] = sub["ten"] * sub["ten"]
    out = sub.persist("TwoZero")
    n = len(out)
    assert n == int((raw["two"] == 0).sum())
    h = out.head(4)
    assert all(h["ten_sq"] == h["ten"] * h["ten"])


def test_open_vs_closed_types(wisconsin_small):
    """Paper 'AFrame' (open) vs 'AFrame Schema' (closed) both answer
    identically; open pays a cast."""
    from repro.engine.session import Session

    t, raw = wisconsin_small
    sess = Session()
    sess.create_dataset("Open", t, dataverse="d", closed=False)
    sess.create_dataset("Closed", t, dataverse="d", closed=True)
    a = AFrame("d", "Open", session=sess)
    b = AFrame("d", "Closed", session=sess)
    assert len(a[a["ten"] == 3]) == len(b[b["ten"] == 3])


def test_lazy_no_execution_until_action(df):
    d, raw = df
    sess = d._session
    before = sess.stats["compiles"] + sess.stats["hits"]
    filtered = d[d["ten"] == 1][["two", "four"]]  # builds plan only
    assert sess.stats["compiles"] + sess.stats["hits"] == before
    assert "WHERE" in filtered.query
