"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs one forward/train step on CPU (output shapes, no
NaNs), plus prefill→decode == full-forward consistency. The FULL configs are
exercised only by the dry-run (ShapeDtypeStructs, never allocated).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models.config import SHAPES, cell_applicable
from repro.models.optim import OptimConfig
from repro.models.registry import batch_specs, get_api
from repro.models.steps import (init_train_state, make_decode_step,
                                make_prefill_step, make_train_step)


def _smoke_batch(cfg, B=2, S=32, seed=1):
    batch = {"tokens": jax.random.randint(jax.random.key(seed), (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (B, cfg.enc_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.key(2), (B, cfg.num_patches, cfg.patch_dim), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_train_smoke(arch):
    cfg = get_config(arch).reduced()
    api = get_api(cfg)
    params, opt = init_train_state(jax.random.key(0), cfg, api)
    batch = _smoke_batch(cfg)
    step = jax.jit(make_train_step(cfg, OptimConfig(total_steps=10), api))
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), m
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(params),
                                jax.tree_util.tree_leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_serve_consistency(arch):
    """prefill(n) + decode(1) logits == prefill(n+1) last logits."""
    cfg = get_config(arch).reduced()
    api = get_api(cfg)
    params = api.init(jax.random.key(0), cfg)
    batch = _smoke_batch(cfg, B=2, S=17)
    full_batch = dict(batch)
    part_batch = dict(batch)
    part_batch["tokens"] = batch["tokens"][:, :16]
    cache_full, logits_full = api.prefill(params, full_batch, cfg, 24)
    cache, _ = api.prefill(params, part_batch, cfg, 24)
    cache, logits_dec = api.decode(params, cache, batch["tokens"][:, 16:17], cfg)
    d = float(jnp.max(jnp.abs(logits_full[:, -1] - logits_dec[:, -1])))
    assert d < 0.1, f"{arch}: prefill/decode mismatch {d}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_loss_decreases(arch):
    cfg = get_config(arch).reduced()
    api = get_api(cfg)
    params, opt = init_train_state(jax.random.key(0), cfg, api)
    batch = _smoke_batch(cfg, B=2, S=32)
    step = jax.jit(make_train_step(cfg, OptimConfig(lr=3e-3, warmup_steps=0,
                                                    total_steps=100), api))
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], f"{arch}: no learning {losses}"


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment block."""
    expect = {
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }
    for arch, (L, d, h, kv, ff, V) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
            == (L, d, h, kv, ff, V), arch
    assert get_config("deepseek-moe-16b").moe.num_experts == 64
    assert get_config("deepseek-moe-16b").moe.top_k == 6
    assert get_config("moonshot-v1-16b-a3b").moe.num_shared == 2
    assert get_config("qwen3-1.7b").qk_norm
    assert get_config("qwen2-72b").qkv_bias
    assert get_config("zamba2-1.2b").ssm_state == 64


def test_long_500k_applicability():
    """Sub-quadratic archs run long_500k; full-attention archs skip."""
    runs = {a: cell_applicable(get_config(a), SHAPES["long_500k"])[0]
            for a in ALL_ARCHS}
    assert runs["rwkv6-1.6b"] and runs["zamba2-1.2b"]
    assert not runs["qwen2-72b"] and not runs["whisper-base"]
    assert sum(runs.values()) == 2


def test_param_counts_are_sane():
    """n_params() within ballpark of the marketing numbers."""
    # moonshot: the ASSIGNED config says 48L × 64 experts, which arithmetically
    # is ~26-28B total (the hf 16B model has 27L); we implement the assignment.
    approx = {"qwen2-72b": 72e9, "qwen2.5-14b": 14e9, "qwen3-1.7b": 1.7e9,
              "command-r-35b": 35e9, "rwkv6-1.6b": 1.6e9,
              "deepseek-moe-16b": 16e9, "moonshot-v1-16b-a3b": 27e9,
              "llava-next-mistral-7b": 7e9, "zamba2-1.2b": 1.2e9}
    for arch, want in approx.items():
        got = get_config(arch).n_params()
        assert 0.5 * want < got < 1.7 * want, (arch, got, want)
    # MoE active params ~3-5B for the (48L) A3B-style moonshot config
    active = get_config("moonshot-v1-16b-a3b").n_active_params()
    assert 1.5e9 < active < 6e9, active
