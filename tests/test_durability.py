"""Durable storage (runtime/durable.py): checksummed segments, the feed
WAL, and Session.open cold-start crash recovery.

The acceptance invariant mirrors the in-memory crash tests: for every I/O
crash point (torn segment/WAL write, pre-manifest-rename, pre-WAL-truncate,
mid-replay) and every execution mode, kill → reopen must serve exactly the
acked state — base rows plus every batch whose push/upsert/delete returned,
in arrival order — bit-identical to an uncrashed oracle, including over
mutated uncompacted data. A batch whose ack itself crashed is allowed (and
required) to vanish. Beyond the crash model, a corrupted segment is
quarantined and the previous manifest generation serves."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.frame import AFrame
from repro.engine import lsm
from repro.engine.ingest import Feed
from repro.engine.session import Session
from repro.engine.table import Table
from repro.runtime import telemetry as tel
from repro.runtime.durable import (StorageCorruption, StorageLockError,
                                   read_segment, write_segment)
from repro.runtime.fault import IO_FAULT_POINTS, FaultPlan, StorageFault

MODES = ["gspmd", "shard_map", "kernel"]

# deferred compaction: crash tests exercise mutated UNCOMPACTED chains
DEFERRED = lsm.CompactionPolicy(size_ratio=100.0, max_runs=64)


def _session(mode, **kw):
    if mode == "shard_map":
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        return Session(mesh=mesh, mode="shard_map", **kw)
    return Session(mode=mode, **kw)


def _create(sess):
    t = Table({"id": np.arange(16, dtype=np.int32),
               "v": np.arange(16, dtype=np.float32),
               "g": (np.arange(16, dtype=np.int32) % 3)})
    sess.create_dataset("ds", t, dataverse="d", primary="id", indexes=["g"])


def _feed(sess):
    return Feed(sess, "ds", "d", flush_rows=10**9, policy=DEFERRED)


# the mutation scenario: an append run, then an upsert/delete run over BOTH
# older components, then more acked-but-unflushed batches (the WAL tail)
BATCHES = [
    ("push", {"id": np.arange(16, 24, dtype=np.int32),
              "v": np.arange(8, dtype=np.float32) * 2.0,
              "g": np.arange(8, dtype=np.int32) % 3}),
    ("flush", None),
    ("upsert", {"id": np.array([1, 17], dtype=np.int32),
                "v": np.array([100.0, 200.0], dtype=np.float32),
                "g": np.array([0, 1], dtype=np.int32)}),
    ("delete", np.array([2, 16], dtype=np.int32)),
    ("flush", None),
    ("upsert", {"id": np.array([3, 30], dtype=np.int32),
                "v": np.array([-1.0, -2.0], dtype=np.float32),
                "g": np.array([2, 2], dtype=np.int32)}),
    ("delete", np.array([5], dtype=np.int32)),
]


def _apply(feed, kind, payload):
    if kind == "flush":
        feed.flush()
    elif kind == "push":
        feed.push(payload)
    elif kind == "upsert":
        feed.upsert(payload)
    else:
        feed.delete(payload)


def _run_batches(sess):
    """Apply BATCHES until the first injected crash; return the mutation
    batches that were ACKED (returned without raising). Flushes are not
    acks — a crashed flush loses nothing already acked."""
    feed = _feed(sess)
    acked = []
    for kind, payload in BATCHES:
        try:
            _apply(feed, kind, payload)
        except StorageFault:
            return acked, True
        if kind != "flush":
            acked.append((kind, payload))
    return acked, False


def _oracle(mode, acked):
    """The uncrashed reference: a memory-only session applying exactly the
    acked batches through the identical ingest path."""
    sess = _session(mode)
    _create(sess)
    feed = _feed(sess)
    for kind, payload in acked:
        _apply(feed, kind, payload)
    feed.flush()
    return _rows(sess)


def _rows(sess):
    got = AFrame("d", "ds", session=sess).collect()
    order = np.argsort(np.asarray(got["id"]), kind="stable")
    return {k: np.asarray(v)[order] for k, v in got.items()}


def _assert_rows_equal(a, b, label=""):
    assert set(a) == set(b), label
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{label}:{k}")


# -- round trip --------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_reopen_roundtrip_bit_identical(tmp_path, mode):
    sess = _session(mode, storage=str(tmp_path))
    _create(sess)
    feed = _feed(sess)
    for kind, payload in BATCHES:
        _apply(feed, kind, payload)
    feed.flush()
    before = _rows(sess)
    sess.close()

    kw = {"mesh": Mesh(np.array(jax.devices()[:1]), ("data",))} \
        if mode == "shard_map" else {}
    re = Session.open(str(tmp_path), mode=mode, **kw)
    _assert_rows_equal(before, _rows(re), f"roundtrip[{mode}]")
    assert re.recovery_report["wal_replayed_batches"] == 0
    # point lookups through the recovered chain: upserted, deleted, absent
    assert re.point_lookup("d", "ds", 1)["v"][0] == 100.0
    assert re.point_lookup("d", "ds", 2) is None
    assert re.point_lookup("d", "ds", 99) is None
    re.close()


# -- crash-restart equivalence: every I/O point × every mode -----------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("point", IO_FAULT_POINTS)
def test_crash_restart_equivalence(tmp_path, mode, point):
    """Kill at the I/O crash point, reopen, and the visible rows are
    bit-identical to the acked-batch oracle — in every execution mode,
    over a mutated uncompacted component chain."""
    kw = {"mesh": Mesh(np.array(jax.devices()[:1]), ("data",))} \
        if mode == "shard_map" else {}
    sess = _session(mode, storage=str(tmp_path))
    _create(sess)
    sess.fault_plan = FaultPlan.once(point)  # arm AFTER the initial commit
    acked, crashed = _run_batches(sess)
    sess.close()

    if point == "mid-replay":
        # the scenario leaves an unflushed acked tail, so a replay happens
        # at reopen — crash between replayed batches, then reopen clean
        with pytest.raises(StorageFault):
            Session.open(str(tmp_path), mode=sess.mode,
                         fault_plan=FaultPlan.once("mid-replay"), **kw)
        crashed = True
    assert crashed or point == "torn-write", point

    re = Session.open(str(tmp_path), mode=sess.mode, **kw)
    _assert_rows_equal(_oracle(mode, acked), _rows(re),
                       f"crash[{point},{mode}]")
    # idempotence: no duplicate primary keys survived the replay
    ids = _rows(re)["id"]
    assert len(ids) == len(set(ids.tolist()))
    re.close()


def test_torn_segment_write_stays_invisible(tmp_path):
    """A torn RUN-SEGMENT write (not the WAL tear): the flush crashes with
    half a segment on disk as a .tmp — never renamed in, so reopen serves
    the previous generation plus the intact WAL tail, and the sweep removes
    the orphan."""
    sess = Session(storage=str(tmp_path))
    _create(sess)
    feed = _feed(sess)
    # arrival 0 is the push's WAL append; arrival 1 is the flush's
    # run-segment write — tear the segment, not the log
    sess.fault_plan = FaultPlan.once("torn-write", arrival=1)
    feed.push({"id": np.arange(16, 24, dtype=np.int32),
               "v": np.arange(8, dtype=np.float32),
               "g": np.zeros(8, dtype=np.int32)})
    with pytest.raises(StorageFault):
        feed.flush()
    seg_dir = tmp_path / "data" / "d" / "ds" / "seg"
    assert list(seg_dir.glob("*.tmp")), "torn write should leave a tmp file"
    sess.close()

    re = Session.open(str(tmp_path))
    assert re.recovery_report["wal_replayed_batches"] == 1
    ids = _rows(re)["id"]
    np.testing.assert_array_equal(ids, np.arange(24, dtype=np.int32))
    assert not list(seg_dir.glob("*.tmp")), "sweep should drop torn tmps"
    re.close()


# -- corruption beyond the crash model ---------------------------------------

def test_corrupt_segment_quarantined_previous_generation_serves(tmp_path):
    sess = Session(storage=str(tmp_path))
    _create(sess)                       # generation 1: base only
    feed = _feed(sess)
    feed.push({"id": np.arange(16, 24, dtype=np.int32),
               "v": np.arange(8, dtype=np.float32),
               "g": np.zeros(8, dtype=np.int32)})
    feed.flush()                        # generation 2: base + run
    sess.close()

    seg_dir = tmp_path / "data" / "d" / "ds" / "seg"
    run_seg = next(p for p in seg_dir.iterdir() if p.name.startswith("run"))
    blob = bytearray(run_seg.read_bytes())
    blob[len(blob) // 2] ^= 0xFF        # flip a payload bit
    run_seg.write_bytes(bytes(blob))

    before = tel.counter_value("storage.corruption_total") or 0
    re = Session.open(str(tmp_path))
    rep = re.recovery_report["datasets"]["d.ds"]
    assert rep["manifest_fallbacks"] >= 1
    assert rep["quarantined"], "corrupt files must be quarantined"
    assert (tel.counter_value("storage.corruption_total") or 0) > before
    assert list((tmp_path / "quarantine").iterdir())
    # the WAL covering the run was truncated at its flush, so the fallback
    # serves exactly the previous generation: the base rows
    ids = _rows(re)["id"]
    np.testing.assert_array_equal(ids, np.arange(16, dtype=np.int32))
    re.close()

    # the fallback is durable: a THIRD open must not trip over the
    # quarantined generation again
    again = Session.open(str(tmp_path))
    np.testing.assert_array_equal(_rows(again)["id"],
                                  np.arange(16, dtype=np.int32))
    again.close()


def test_segment_checksum_rejects_bit_flip(tmp_path):
    path = tmp_path / "x.seg"
    write_segment(path, {"a": np.arange(10, dtype=np.int64)}, {"k": 1},
                  lambda point: None)
    arrays, meta = read_segment(path)
    np.testing.assert_array_equal(arrays["a"], np.arange(10))
    assert meta["k"] == 1
    blob = bytearray(path.read_bytes())
    blob[-3] ^= 0x01
    path.write_bytes(bytes(blob))
    with pytest.raises(StorageCorruption):
        read_segment(path)


# -- WAL edge cases ----------------------------------------------------------

def test_empty_buffer_flush_is_noop(tmp_path):
    sess = Session(storage=str(tmp_path))
    _create(sess)
    feed = _feed(sess)
    ds_dir = tmp_path / "data" / "d" / "ds"
    gens_before = sorted(p.name for p in ds_dir.glob("MANIFEST.*.json"))
    feed.flush()
    feed.flush()
    assert sorted(p.name for p in ds_dir.glob("MANIFEST.*.json")) == gens_before
    assert sess.storage.wal_seq("d", "ds") == 0
    sess.close()


def test_replay_skips_already_flushed_batches(tmp_path):
    """Crash between manifest commit and WAL truncate: the covered records
    are still in the log but the manifest's wal_upto fences them — replay
    skips, no rows duplicate."""
    sess = Session(storage=str(tmp_path))
    _create(sess)
    feed = _feed(sess)
    feed.push({"id": np.arange(16, 24, dtype=np.int32),
               "v": np.arange(8, dtype=np.float32),
               "g": np.zeros(8, dtype=np.int32)})
    sess.fault_plan = FaultPlan.once("pre-wal-truncate")
    with pytest.raises(StorageFault):
        feed.flush()
    sess.close()
    # the record is physically still in the log...
    assert (tmp_path / "data" / "d" / "ds" / "wal.log").stat().st_size > 0

    re = Session.open(str(tmp_path))
    # ...but fenced: nothing replays, and the rows appear exactly once
    assert re.recovery_report["wal_replayed_batches"] == 0
    ids = _rows(re)["id"]
    np.testing.assert_array_equal(ids, np.arange(24, dtype=np.int32))
    re.close()


def test_interleaved_upsert_delete_replay_order(tmp_path):
    """Replay applies the tail in arrival order: upsert → delete → upsert
    of the SAME key must land on the last value, not resurrect the
    tombstone or the first upsert."""
    sess = Session(storage=str(tmp_path))
    _create(sess)
    feed = _feed(sess)
    k = np.array([100], dtype=np.int32)
    g = np.array([0], dtype=np.int32)
    feed.upsert({"id": k, "v": np.array([1.0], np.float32), "g": g})
    feed.delete(k)
    feed.upsert({"id": k, "v": np.array([2.0], np.float32), "g": g})
    feed.delete(np.array([7], dtype=np.int32))
    sess.close()   # acked, never flushed: all four live only in the WAL

    re = Session.open(str(tmp_path))
    assert re.recovery_report["wal_replayed_batches"] == 4
    assert re.point_lookup("d", "ds", 100)["v"][0] == 2.0
    assert re.point_lookup("d", "ds", 7) is None
    re.close()


def test_double_open_raises_lock_error(tmp_path):
    sess = Session(storage=str(tmp_path))
    _create(sess)
    with pytest.raises(StorageLockError):
        Session.open(str(tmp_path))
    sess.close()
    re = Session.open(str(tmp_path))   # released lock -> clean open
    re.close()


# -- lazy soft-state rebuild -------------------------------------------------

def test_lazy_rebuild_defers_to_first_bind(tmp_path):
    sess = Session(storage=str(tmp_path))
    _create(sess)
    feed = _feed(sess)
    feed.upsert({"id": np.array([1], np.int32), "v": np.array([9.0], np.float32),
                 "g": np.array([0], np.int32)})
    feed.flush()
    expect = _rows(sess)
    sess.close()

    re = Session.open(str(tmp_path), lazy=True)
    assert re.catalog.stale, "lazy open must defer the soft rebuild"
    comps = re.catalog.get("d", "ds")
    assert comps.soft_stale and comps.indexes["primary"].sorted_keys is None
    before = tel.counter_value("storage.lazy_rebuilds_total") or 0
    _assert_rows_equal(expect, _rows(re), "lazy")     # first bind rebuilds
    assert not re.catalog.stale
    assert not comps.soft_stale
    assert comps.indexes["primary"].sorted_keys is not None
    assert (tel.counter_value("storage.lazy_rebuilds_total") or 0) > before
    assert re.point_lookup("d", "ds", 1)["v"][0] == 9.0
    re.close()

    eager = Session.open(str(tmp_path), lazy=False)
    assert not eager.catalog.stale
    assert not eager.catalog.get("d", "ds").soft_stale
    _assert_rows_equal(expect, _rows(eager), "eager")
    eager.close()


# -- telemetry & retired-segment GC ------------------------------------------

def test_recovery_telemetry_series_present(tmp_path):
    sess = Session(storage=str(tmp_path))
    _create(sess)
    sess.close()
    re = Session.open(str(tmp_path))
    assert tel.counter_value("storage.wal_replayed_batches_total") is not None
    assert tel.counter_value("storage.corruption_total") is not None
    assert re.recovery_report["seconds"] >= 0.0
    re.close()


def test_compaction_gc_unlinks_dead_segments(tmp_path):
    """After compaction folds the chain and old generations age out of the
    keep window, the retired run segments disappear from disk."""
    sess = Session(storage=str(tmp_path))
    _create(sess)
    feed = Feed(sess, "ds", "d", flush_rows=10**9,
                policy=lsm.CompactionPolicy(size_ratio=0.0))  # compact always
    for i in range(4):
        feed.push({"id": np.arange(100 + 8 * i, 108 + 8 * i, dtype=np.int32),
                   "v": np.full(8, float(i), np.float32),
                   "g": np.zeros(8, np.int32)})
        feed.flush()
    expect = _rows(sess)
    seg_dir = tmp_path / "data" / "d" / "ds" / "seg"
    segs = {p.name for p in seg_dir.iterdir()}
    # compact-every-flush keeps the chain flat: old run/base segments are
    # referenced only by aged-out generations and must be unlinked
    assert len(segs) <= 2 * sess.storage.keep_manifests
    sess.close()
    re = Session.open(str(tmp_path))
    _assert_rows_equal(expect, _rows(re), "post-gc")
    re.close()
