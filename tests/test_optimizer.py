"""Optimizer rewrite rules (the AsterixDB query-optimizer analogue) and the
cost-based physical planner's access-path choices (logical→physical split:
the optimizer only rewrites; index-vs-scan-vs-kernel lives in the planner)."""
import pytest

from repro.core import physical as PH
from repro.core import plan as P
from repro.core.expr import BoolOp, Col, Compare, Lit, StrUpper
from repro.core.optimizer import optimize
from repro.core.physical_planner import plan_physical
from repro.core.catalog import Catalog, Dataset
from repro.data import wisconsin
from repro.engine.session import Session


@pytest.fixture(scope="module")
def catalog():
    sess = Session()
    sess.create_dataset("Data", wisconsin.generate(1000), dataverse="d",
                        indexes=["onePercent"], primary="unique2")
    return sess.catalog


def scan():
    return P.Scan("Data", "d")


def test_fuse_filters(catalog):
    p = P.Filter(P.Filter(scan(), Compare("==", Col("a"), Lit(1))),
                 Compare("==", Col("b"), Lit(2)))
    opt = optimize(p, catalog, enable_index=False)
    assert isinstance(opt, P.Filter)
    assert isinstance(opt.children[0], P.Scan)
    assert isinstance(opt.predicate, BoolOp)


def test_limit_sort_becomes_topk(catalog):
    p = P.Limit(P.Sort(scan(), "unique1", False), 5)
    opt = optimize(p, catalog)
    assert isinstance(opt, P.TopK)
    assert opt.k == 5 and not opt.ascending


def test_limit_pushes_below_project(catalog):
    """The paper's expression-5 win: the UDF runs on n rows, not the table."""
    p = P.Limit(P.Project(scan(), [("u", StrUpper(Col("stringu1")))]), 5)
    opt = optimize(p, catalog)
    assert isinstance(opt, P.Project)
    assert isinstance(opt.children[0], P.Limit)


def test_count_filter_fuses(catalog):
    p = P.Agg(P.Filter(scan(), Compare("==", Col("ten"), Lit(1))),
              [P.AggSpec("count", "count", None)])
    opt = optimize(p, catalog, enable_index=False)
    assert isinstance(opt, P.FilterCount)


def test_count_join_fuses(catalog):
    p = P.Agg(P.Join(scan(), scan(), "unique1", "unique1"),
              [P.AggSpec("count", "count", None)])
    opt = optimize(p, catalog)
    assert isinstance(opt, P.JoinCount)


def test_index_selected_for_range(catalog):
    """Paper expression 11: range count -> index-only query. The choice is
    now COSTED in the physical planner: an index probe (binary search) must
    beat the full scan, and the optimizer output stays purely logical."""
    pred = BoolOp("AND", Compare(">=", Col("onePercent"), Lit(10)),
                  Compare("<=", Col("onePercent"), Lit(30)))
    p = P.Agg(P.Filter(scan(), pred), [P.AggSpec("count", "count", None)])
    opt = optimize(p, catalog)
    assert isinstance(opt, P.FilterCount)          # logical fusion only
    assert isinstance(opt.children[0], (P.Scan, P.Project))
    phys = plan_physical(opt, catalog)
    assert isinstance(phys, PH.IndexOnlyCount)
    assert phys.index_col == "onePercent"
    assert phys.cost < plan_physical(opt, catalog,
                                     enable_index=False).total_cost()
    assert "chosen over" in phys.note              # the costed alternatives


def test_index_point_with_residual(catalog):
    pred = BoolOp("AND", Compare("==", Col("onePercent"), Lit(10)),
                  Compare("==", Col("two"), Lit(1)))
    p = P.Filter(scan(), pred)
    opt = optimize(p, catalog)
    assert isinstance(opt, P.Filter)               # optimizer: no access path
    phys = plan_physical(opt, catalog)
    assert isinstance(phys, PH.IndexProbe)
    assert phys.residual is not None


def test_no_index_without_catalog_entry(catalog):
    pred = Compare(">=", Col("twenty"), Lit(3))
    p = P.Filter(scan(), pred)
    phys = plan_physical(optimize(p, catalog), catalog)
    assert isinstance(phys, PH.FullScanFilter)  # twenty is not indexed


def test_column_pruning_inserts_narrow_project(catalog):
    p = P.Agg(scan(), [P.AggSpec("m", "max", "unique1")])
    opt = optimize(p, catalog, enable_index=False)
    # the scan should now be wrapped in a single-column project
    inner = opt.children[0]
    assert isinstance(inner, P.Project)
    assert [n for n, _ in inner.outputs] == ["unique1"]


def test_point_then_range_cache_collision():
    """Regression (found by hypothesis): a point predicate (== v) and a range
    predicate (>= a AND <= b) on an indexed column share a plan fingerprint;
    the point plan must NOT alias one Lit as both bounds or a later cache hit
    cross-binds the range literals."""
    import numpy as np
    from repro.data import wisconsin
    from repro.engine.session import Session

    t = wisconsin.generate(2000, seed=7)
    raw = np.asarray(t.columns["onePercent"])
    sess = Session()
    sess.create_dataset("D", t, dataverse="r", indexes=["onePercent"])
    point = P.Agg(P.Filter(P.Scan("D", "r"), Compare("==", Col("onePercent"), Lit(3))),
                  [P.AggSpec("count", "count", None)])
    assert sess.execute(point) == int((raw == 3).sum())
    rng = P.Agg(P.Filter(P.Scan("D", "r"),
                         BoolOp("AND", Compare(">=", Col("onePercent"), Lit(0)),
                                Compare("<=", Col("onePercent"), Lit(1)))),
                [P.AggSpec("count", "count", None)])
    assert sess.execute(rng) == int(((raw >= 0) & (raw <= 1)).sum())
    assert sess.stats["hits"] == 1  # same fingerprint, correct rebinding


def test_optimizer_disabled_modes(catalog):
    pred = BoolOp("AND", Compare(">=", Col("onePercent"), Lit(10)),
                  Compare("<=", Col("onePercent"), Lit(30)))
    p = P.Agg(P.Filter(scan(), pred), [P.AggSpec("count", "count", None)])
    opt = optimize(p, catalog, enable_index=False, enable_pushdown=False)
    assert isinstance(opt, P.Agg)
