"""Expression AST + SQL++ rendering (paper Fig. 3 Inputs 7/8, Appendix C)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.expr import (Arith, BoolOp, Col, Compare, IsKnown, Lit,
                             StrUpper, collect_params, param_values)
from repro.core import plan as P
from repro.core.frame import AFrame
from repro.engine.session import Session
from repro.engine.table import Table, encode_strings


def test_compare_sql():
    e = Compare("==", Col("ten"), Lit(5))
    assert e.to_sql() == "t.ten = 5"
    assert e.columns() == {"ten"}


def test_boolop_sql():
    e = BoolOp("AND", Compare(">=", Col("a"), Lit(1)), Compare("<=", Col("a"), Lit(9)))
    assert e.to_sql() == "(t.a >= 1 AND t.a <= 9)"


def test_isknown_matches_paper_input7():
    e = IsKnown(Col("coordinate"))
    assert e.to_sql() == "t.coordinate IS KNOWN"


def test_upper_sql():
    assert StrUpper(Col("stringu1")).to_sql() == "UPPER(t.stringu1)"


def test_eval_numeric():
    env = {"x": jnp.asarray([1, 2, 3, 4])}
    e = (Compare("<", Col("x"), Lit(3)))
    lits = collect_params([e])
    out = e.evaluate(env, param_values(lits))
    assert list(np.asarray(out)) == [True, True, False, False]


def test_eval_string_equality():
    env = {"s": jnp.asarray(encode_strings(["abc", "abd", "abc"]))}
    e = Compare("==", Col("s"), Lit("abc"))
    lits = collect_params([e])
    out = e.evaluate(env, param_values(lits))
    assert list(np.asarray(out)) == [True, False, True]


def test_fingerprint_excludes_literal_values():
    a = Compare("==", Col("x"), Lit(3))
    b = Compare("==", Col("x"), Lit(99))
    assert a.fingerprint() == b.fingerprint()


def test_arith_eval():
    env = {"x": jnp.asarray([2, 4])}
    e = Arith("*", Col("x"), Lit(3))
    lits = collect_params([e])
    assert list(np.asarray(e.evaluate(env, param_values(lits)))) == [6, 12]


# -- plan SQL++ matches paper appendix C patterns ------------------------------


@pytest.fixture(scope="module")
def df():
    from repro.data import wisconsin

    sess = Session()
    sess.create_dataset("Data", wisconsin.generate(100), dataverse="d")
    return AFrame("d", "Data", session=sess)


def test_scan_sql(df):
    assert df.query == "SELECT VALUE t FROM d.Data t;"


def test_filter_sql(df):
    q = df[df["ten"] == 3].query
    assert "WHERE t.ten = 3" in q


def test_limit_sql(df):
    q = P.Limit(df._plan, 5).to_sql()
    assert q.endswith("LIMIT 5")


def test_groupby_sql(df):
    plan = P.GroupAgg(df._plan, ["oddOnePercent"],
                      [P.AggSpec("cnt", "count", None)])
    q = plan.to_sql()
    assert "GROUP BY t.oddOnePercent" in q and "COUNT(*) AS cnt" in q


def test_join_count_sql(df):
    plan = P.JoinCount(df._plan, df._plan, "unique1", "unique1")
    q = plan.to_sql()
    assert "JOIN" in q and "COUNT(*)" in q and "l.unique1 = r.unique1" in q


def test_plan_cache_hit(df):
    sess = df._session
    before = dict(sess.stats)
    len(df[df["ten"] == 1])
    mid = dict(sess.stats)
    len(df[df["ten"] == 7])  # different literal, same fingerprint
    after = dict(sess.stats)
    assert after["compiles"] == mid["compiles"]
    assert after["hits"] == mid["hits"] + 1
